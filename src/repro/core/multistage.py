"""ColBERT-serve's multi-stage retrieval pipeline.

Four systems, exactly as the paper's evaluation defines them:

  * ``colbert``  — full PLAID end-to-end (in-memory or MMAP per store mode)
  * ``splade``   — SPLADEv2 w/ PISA-style impact index only
  * ``rerank``   — SPLADE top-``first_k`` → MMAP ColBERT exact rescoring
  * ``hybrid``   — rerank + α-interpolated z-normed score fusion
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hybrid as hybrid_mod
from repro.core.plaid import (
    PLAIDSearcher,
    _pad_batch_rows,
    pad_query_batch,
    pad_query_batch_host,
)
from repro.index.splade_device import SpladeDeviceCache
from repro.index.splade_index import SpladeIndex
from repro.kernels.fused_rerank import ops as fused_ops
from repro.serving.context import BatchOutcome, freeze
from repro.serving.pipeline import (
    DEVICE,
    HOST,
    CandidateBatch,
    PipelineStats,
    Stage,
    StagePlan,
)

SPLADE_BACKENDS = ("host", "jax", "pallas")
RERANK_BACKENDS = ("fused", "split")
METHODS = ("colbert", "splade", "rerank", "hybrid")


@dataclasses.dataclass(frozen=True)
class MultiStageParams:
    first_k: int = 200            # SPLADE candidates (paper: top-200)
    k: int = 100                  # final depth
    alpha: float = 0.3            # paper's MS MARCO-tuned value
    normalizer: str = "znorm"
    splade_backend: str = "host"  # stage-1 scorer: host | jax | pallas
    splade_max_df: Optional[int] = None  # padded-postings df cap (None=exact)
    rerank_backend: str = "fused"  # stage-4 tail: fused | split


class MultiStageRetriever:
    # coordinator cache hierarchy (attached by the engine) and the index
    # generation its entries are scoped to. Class-level defaults so the
    # sharded subclasses — which build themselves without calling this
    # __init__ — inherit a disabled-cache state for free.
    _caches = None
    index_generation: int = 0
    # live (mutable) index state: None = frozen serving (default; every
    # pre-live code path is untouched), a LiveIndexState on the owner
    # retriever, or a LiveView on shard-level / worker retrievers
    live = None

    def __init__(self, splade_index: SpladeIndex, searcher: PLAIDSearcher,
                 params: MultiStageParams = MultiStageParams(),
                 device=None):
        """``device`` (optional jax.Device) pins this retriever's
        device-resident stage-1 state — under a shard group each shard
        lands on its own mesh device (``launch.mesh.shard_device_map``)
        so per-shard dispatches execute in parallel."""
        self.splade = splade_index
        self.searcher = searcher
        self.params = params
        self.device = device
        self._splade_device: Optional[SpladeDeviceCache] = None
        self._lock = threading.Lock()
        self._plans: dict = {}
        # single per-stage instrumentation record (wall time, dispatches,
        # queue wait, mmap pages/tokens, overlap) — reset in place so
        # pipeline executors can hold a stable reference
        self.pipeline_stats = PipelineStats()
        self.set_splade_backend(params.splade_backend)  # validates
        self.set_rerank_backend(params.rerank_backend)
        self.reset_stage_stats()
        if params.splade_backend != "host":
            self.splade_device_cache()    # pay the transfer up front

    # ------------------------------------------------------------------
    # stage-1 backend selection
    # ------------------------------------------------------------------
    def set_splade_backend(self, backend: str):
        if backend not in SPLADE_BACKENDS:
            raise ValueError(f"splade backend {backend!r} not in "
                             f"{SPLADE_BACKENDS}")
        self.splade_backend = backend

    def set_rerank_backend(self, backend: str):
        """Stage-4 tail selection: ``fused`` collapses exact scoring,
        masking, (hybrid) α-fusion and top-k selection into ONE device
        dispatch (the ``fused_rerank`` kernel / fused-XLA tail);
        ``split`` keeps the legacy multi-dispatch tail. Results are
        bitwise-identical — ``fused`` silently degrades to ``split``
        when the Pallas toolchain is absent."""
        if backend not in RERANK_BACKENDS:
            raise ValueError(f"rerank backend {backend!r} not in "
                             f"{RERANK_BACKENDS}")
        if backend == "fused" and not fused_ops.HAVE_PALLAS:
            backend = "split"
        self.rerank_backend = backend

    def splade_device_cache(self) -> SpladeDeviceCache:
        """Padded-postings device arrays, materialised once and reused
        across every jax/pallas stage-1 dispatch (locked: concurrent
        server workers must not each pay the host→device transfer)."""
        with self._lock:
            if self._splade_device is None:
                self._splade_device = SpladeDeviceCache(
                    self.splade, max_df=self.params.splade_max_df,
                    device=self.device)
            return self._splade_device

    def _splade_impl(self, backend: str) -> str:
        # the Pallas kernel body runs in interpret mode off-TPU so the
        # selector stays honest (same code path, Mosaic-free execution)
        if backend == "jax":
            return "ref"
        return "pallas" if jax.default_backend() == "tpu" else "interpret"

    def reset_stage_stats(self):
        """Clear the per-stage instrumentation (in place: executors and
        benchmarks keep a stable reference to ``pipeline_stats``)."""
        self.pipeline_stats.reset()

    @property
    def stage_stats(self) -> dict:
        """Legacy view of :attr:`pipeline_stats`: stage-1 wall time /
        dispatch count vs everything after (stages 2–4 + fusion)."""
        stages = self.pipeline_stats.snapshot()["stages"]
        s1 = stages.get("splade_stage1", {})
        return {"stage1_s": s1.get("wall_s", 0.0),
                "stage1_dispatches": s1.get("dispatches", 0),
                "stage1_queries": s1.get("queries", 0),
                "rest_s": sum(r["wall_s"] for name, r in stages.items()
                              if name != "splade_stage1")}

    # ------------------------------------------------------------------
    # coordinator cache hierarchy + index-generation invalidation
    # ------------------------------------------------------------------
    def attach_caches(self, caches):
        """Attach a :class:`~repro.serving.context.CacheHierarchy`.
        Plans close over ``self`` and read ``self._caches`` per call, so
        caches can be attached (or detached with ``None``) after plans
        are compiled."""
        self._caches = caches

    def bump_index_generation(self):
        """Advance the index generation (an index mutation — upsert,
        delete, reshard — happened) and purge every cache entry computed
        under an older generation. New cache keys embed the new
        generation, so stale entries can never be served even before the
        purge completes."""
        self.index_generation = self.index_generation + 1
        caches = self._caches
        if caches is not None:
            caches.purge_stale(self.index_generation)
        return self.index_generation

    def _plaid_salt(self) -> str:
        sp = self.searcher.params
        return f"np{sp.nprobe}|cc{sp.candidate_cap}|nd{sp.ndocs}"

    def cache_salts(self, method: str):
        """(exact_salt, stage1_salt): the retriever-config components of
        the cache keys. Everything that changes an answer for identical
        query bytes must appear here — backends, first_k, normalizer,
        PLAID knobs, and the index generation."""
        p = self.params
        gen = self.index_generation
        if method == "colbert":
            s1 = f"cand|{self._plaid_salt()}|g{gen}"
        else:
            s1 = f"sp|fk{p.first_k}|b{self.splade_backend}|g{gen}"
        exact = (f"fk{p.first_k}|n{p.normalizer}|sb{self.splade_backend}"
                 f"|rb{self.rerank_backend}|{self._plaid_salt()}|g{gen}")
        return exact, s1

    def _stage1_ctx_keys(self, cb: CandidateBatch):
        """Per-query stage-1 cache keys for a batch, or None when the
        stage-1 cache is off / the batch carries no contexts."""
        caches = self._caches
        if (caches is None or caches.stage1.capacity <= 0
                or cb.ctxs is None):
            return None
        keys = [None if c is None else c.stage1_key for c in cb.ctxs]
        if all(k is None for k in keys):
            return None
        return keys

    def _stage1_group_lookup(self, cb: CandidateBatch):
        """All-or-nothing batch lookup of merged stage-1 rows (the
        sharded plans' granularity: a partial hit recomputes the whole
        batch, since the per-shard fanout runs all queries together).
        Returns stacked ``(pids_b, s_scores)`` or None."""
        keys = self._stage1_ctx_keys(cb)
        if keys is None:
            return None
        rows = [None if k is None else self._caches.stage1.get(k)
                for k in keys]
        n_hit = sum(r is not None for r in rows)
        if n_hit < len(rows):
            self.pipeline_stats.counter("cache_stage1_misses",
                                        len(rows) - n_hit)
            return None
        self.pipeline_stats.counter("cache_stage1_hits", n_hit)
        return (np.stack([r[0] for r in rows]),
                np.stack([r[1] for r in rows]))

    def _stage1_group_store(self, cb: CandidateBatch):
        """Store merged stage-1 rows (full ``first_k`` width) per query.
        Skipped for degraded batches — a candidate union missing a
        shard's postings must never be replayed as a full answer."""
        keys = self._stage1_ctx_keys(cb)
        if keys is None or cb.state.get("missing_shards"):
            return
        pids_b = cb.state.get("pids_b")
        s_scores = cb.state.get("s_scores")
        if pids_b is None or s_scores is None:
            return
        gen = self.index_generation
        for i, key in enumerate(keys):
            if key is not None:
                self._caches.stage1.put(
                    key, freeze(pids_b[i], s_scores[i]), gen)

    # ------------------------------------------------------------------
    def run_splade(self, term_ids, term_weights, k: Optional[int] = None,
                   backend: Optional[str] = None):
        pids, scores = self.run_splade_batch(
            [term_ids], [term_weights], k=k, backend=backend)
        return pids[0], scores[0]

    def run_splade_batch(self, term_ids, term_weights,
                         k: Optional[int] = None,
                         backend: Optional[str] = None,
                         _record: bool = True):
        """Stage 1 for a whole micro-batch in one dispatch.

        term_ids/term_weights: sequences of per-query (Qt_i,) arrays.
        backend 'host' → vectorised CSR pass (`score_batch_host`);
        'jax'/'pallas' → device-resident padded postings (segment-sum /
        block kernel) with a fused per-query top-k. ``_record=False``
        skips stats (the plan runner accounts the stage itself)."""
        backend = backend or self.splade_backend
        if backend not in SPLADE_BACKENDS:
            raise ValueError(f"splade backend {backend!r} not in "
                             f"{SPLADE_BACKENDS}")
        k = self.params.first_k if k is None else k
        t0 = time.perf_counter()
        live = self.live
        if live is not None and live.dirty:
            # live serving always scores stage 1 on the host CSR: the
            # tombstone exclusion must happen *pre-top-k* (a masked doc
            # may not displace a survivor) and the delta segment is
            # host-resident. Cache keys embed the generation, which a
            # mutation bumps, so entries never mix backends within one
            # generation.
            out = self._run_splade_live(live, term_ids, term_weights, k)
        elif backend == "host":
            out = self.splade.score_batch_host(term_ids, term_weights, k)
        else:
            cache = self.splade_device_cache()
            out = cache.score_topk(term_ids, term_weights, k,
                                   impl=self._splade_impl(backend))
        if _record:
            self.pipeline_stats.record(
                "splade_stage1", HOST if backend == "host" else DEVICE,
                time.perf_counter() - t0, queries=len(term_ids))
        return out

    def _run_splade_live(self, live, term_ids, term_weights, k: int):
        """Stage 1 under a dirty live state: base CSR scoring with
        tombstoned base pids excluded pre-top-k, merged with the delta
        segment's own top-k (owner retrievers only — shard-level
        ``LiveView``s carry tombstones but no delta; delta docs merge at
        the coordinator). The merge of disjoint-partition top-k lists
        under (score desc, pid asc) equals the top-k of the union — the
        same invariant the sharded fan-out relies on — so the result is
        exactly what one index over base∪delta minus tombstones scores."""
        base = self.splade.score_batch_host(term_ids, term_weights, k,
                                            exclude=live.base_exclude)
        delta_fn = getattr(live, "splade_delta_topk", None)
        if delta_fn is None:
            return base
        d_pids, d_scores = delta_fn(term_ids, term_weights, k)
        from repro.core.sharded import merge_topk
        return merge_topk(
            np.concatenate([base[0].astype(np.int64), d_pids], axis=1),
            np.concatenate([base[1], d_scores], axis=1), k, pad_score=0.0)

    # ------------------------------------------------------------------
    def search(self, method: str, q_emb=None, term_ids=None,
               term_weights=None, alpha: Optional[float] = None,
               k: Optional[int] = None):
        """Returns (pids (k,), scores (k,)), -1 padded, descending."""
        p = self.params
        k = p.k if k is None else k
        alpha = p.alpha if alpha is None else alpha

        live = self.live
        if live is not None and live.dirty:
            # single queries route through the (gated, overlay-aware)
            # batch path while the live state is dirty
            pids, scores, _ = self.search_batch_ctx(
                method,
                q_embs=None if q_emb is None else [q_emb],
                term_ids=None if term_ids is None else [term_ids],
                term_weights=None if term_weights is None else [term_weights],
                alpha=alpha, k=k)
            return pids[0], scores[0]

        if method == "colbert":
            pids, scores, _ = self.searcher.search(q_emb, k=k)
            return pids, scores

        pids, s_scores = self.run_splade(term_ids, term_weights, p.first_k)
        if method == "splade":
            return pids[:k], s_scores[:k]

        t0 = time.perf_counter()
        c_scores = self.searcher.rerank(q_emb, pids)
        mask = pids >= 0
        if method == "rerank":
            final = np.where(mask, c_scores, -np.inf)
        elif method == "hybrid":
            final = np.asarray(hybrid_mod.hybrid_scores(
                jnp.asarray(s_scores), jnp.asarray(c_scores),
                jnp.asarray(mask), alpha=alpha, normalizer=p.normalizer))
        else:
            raise ValueError(method)

        order = np.argsort(-final, kind="stable")[:k]
        out_pids = np.where(final[order] > -np.inf, pids[order], -1)
        self.pipeline_stats.record("rest", HOST,
                                   time.perf_counter() - t0, queries=1)
        return out_pids, final[order]

    # ------------------------------------------------------------------
    # stage-graph compilation (the serving pipeline's unit of execution)
    # ------------------------------------------------------------------
    def build_batch(self, method: str, q_embs=None, term_ids=None,
                    term_weights=None, alphas=None, k: Optional[int] = None,
                    n: Optional[int] = None,
                    ctxs=None) -> CandidateBatch:
        """Package per-query inputs into the immutable carrier a
        :class:`StagePlan` consumes. ``ctxs`` (optional per-query
        :class:`~repro.serving.context.RequestContext`) rides along so
        plan stages can consult per-request cache keys."""
        k = self.params.k if k is None else k
        if n is None:
            n = len(q_embs) if q_embs is not None else len(term_ids)
        pick = (lambda seq: None if seq is None else tuple(seq[:n]))
        return CandidateBatch(method=method, k=k, q_embs=pick(q_embs),
                              term_ids=pick(term_ids),
                              term_weights=pick(term_weights),
                              alphas=alphas, ctxs=pick(ctxs))

    def compile_plan(self, method: str) -> StagePlan:
        """Compile one of the four systems to its typed stage graph.

        Plans are cached per (method, stage-1 backend, rerank backend);
        the stage functions close over ``self`` and read dynamic state
        (backend, device caches) at run time. The synchronous
        :meth:`search_batch` and the pipelined executor both run the
        plan returned here, so depth-1 vs depth-N results are
        method-faithful by construction.
        """
        if method not in METHODS:
            raise ValueError(method)
        key = (method, self.splade_backend, self.rerank_backend)
        with self._lock:
            # one plan object per key: the engine keys live executors on
            # plan identity, so two racing builders must not each get a
            # distinct (but equivalent) plan
            plan = self._plans.get(key)
            if plan is None:
                plan = self._plans[key] = self._build_plan(method)
            return plan

    def _build_plan(self, method: str) -> StagePlan:
        """Stage functions obey a strict resource discipline: host-kind
        stages touch ONLY numpy (mmap gathers, padding, formatting) and
        never call into jax, because a host stage that device_puts or
        blocks on a device value serialises behind the device worker's
        in-flight dispatch and the pipeline loses its overlap. All
        host↔device transfers and result syncs live inside device-kind
        stages, so they are attributed to (and overlapped by) the
        device worker."""
        p = self.params
        searcher = self.searcher
        dr = searcher.device_resident
        gather_kind = DEVICE if dr else HOST
        access = None if dr else searcher.index.store.stats

        if method == "colbert":
            def probe(cb):
                # candidate-cache probe: when EVERY query's post-approx
                # survivor set is cached, skip stages 1-3 entirely and
                # rebuild the padded state the rerank tail consumes.
                # Batch padding replicates the last real row — exactly
                # what the cold path's deterministic device stages
                # produce for pad rows — so downstream gathers see
                # byte-identical inputs.
                keys = self._stage1_ctx_keys(cb)
                if keys is not None:
                    rows = [None if k_ is None
                            else self._caches.stage1.get(k_)
                            for k_ in keys]
                    if all(r is not None for r in rows):
                        self.pipeline_stats.counter("cache_stage1_hits",
                                                    len(rows))
                        q, q_valid = pad_query_batch(cb.q_embs)
                        B, q, q_valid, final_np = _pad_batch_rows(
                            q, q_valid, np.stack([r[0] for r in rows]))
                        n_real = np.asarray([int(r[1]) for r in rows])
                        return cb.with_state(
                            B=B, q=q, q_valid=q_valid,
                            final_pids=jnp.asarray(final_np),
                            final_np=final_np, n_real=n_real,
                            stage1_cached=True)
                    self.pipeline_stats.counter(
                        "cache_stage1_misses",
                        sum(r is None for r in rows))
                st = searcher.probe_batch(cb.q_embs)
                # sync candidates to host here, on the device worker —
                # the host gather must not block on device work
                st["cand_np"] = np.asarray(st["cand"])
                return cb.with_state(**st)

            def gather_codes(cb):
                if cb.state.get("stage1_cached"):
                    return cb
                s = cb.state
                n_real = (s["cand_np"][:s["B"]] >= 0).sum(axis=1)
                if dr:
                    codes, valid = searcher.gather_codes_batch(s["cand"])
                else:
                    codes, _, valid = searcher._dedup_gather(
                        s["cand_np"], codes_only=True)
                return cb.with_state(codes=codes, cvalid=valid,
                                     n_real=n_real)

            def approx(cb):
                if cb.state.get("stage1_cached"):
                    return cb
                s = cb.state
                final_pids = searcher.approx_select_batch(
                    s["scores_c"], jnp.asarray(s["codes"]),
                    jnp.asarray(s["cvalid"]), s["q_valid"], s["cand"])
                final_np = np.asarray(final_pids)
                keys = self._stage1_ctx_keys(cb)
                if keys is not None:
                    gen = self.index_generation
                    for i, key in enumerate(keys):
                        if key is not None:
                            self._caches.stage1.put(
                                key,
                                (freeze(final_np[i])[0],
                                 int(s["n_real"][i])), gen)
                return cb.with_state(final_pids=final_pids,
                                     final_np=final_np)

            def gather_residuals(cb):
                s = cb.state
                if dr:
                    f_codes, f_packed, f_valid = \
                        searcher.gather_tokens_batch(s["final_pids"])
                else:
                    f_codes, f_packed, f_valid = searcher._dedup_gather(
                        s["final_np"], codes_only=False)
                return cb.with_state(f_codes=f_codes, f_packed=f_packed,
                                     f_valid=f_valid)

            def exact(cb):
                s = cb.state
                ex = searcher.exact_score_gathered(
                    s["q"], s["q_valid"], jnp.asarray(s["f_codes"]),
                    jnp.asarray(s["f_packed"]), jnp.asarray(s["f_valid"]),
                    s["final_pids"])
                pids, scores = searcher.finalize_topk(
                    ex, s["final_pids"], s["B"], cb.k)
                return cb.with_state(out_pids=pids, out_scores=scores)

            def fuse(cb):
                s = cb.state
                aux = [{"candidates": int(x)} for x in s["n_real"]]
                return cb.evolve(pids=s["out_pids"],
                                 scores=s["out_scores"]).with_state(aux=aux)

            def exact_fused(cb):
                # fused stage-4 tail: decompress + MaxSim + top-k in ONE
                # dispatch (no materialised (B, C) score tensor on the
                # kernel path), then host-side pid mapping — replaces
                # device_score:exact (2 dispatches) + fuse_topk's
                # finalize (top_k + take_along_axis)
                s = cb.state
                top_s, top_i = searcher.fused_topk_gathered(
                    s["q"], s["q_valid"], jnp.asarray(s["f_codes"]),
                    jnp.asarray(s["f_packed"]), jnp.asarray(s["f_valid"]),
                    s["final_np"] >= 0, cb.k)
                pids, scores = searcher.finalize_topk_fused(
                    top_s, top_i, s["final_np"], s["B"], cb.k)
                aux = [{"candidates": int(x)} for x in s["n_real"]]
                return cb.evolve(pids=pids,
                                 scores=scores).with_state(aux=aux)

            head = (Stage("plaid_probe", DEVICE, probe),
                    Stage("host_gather:codes", gather_kind, gather_codes),
                    Stage("device_score:approx", DEVICE, approx),
                    Stage("host_gather:residuals", gather_kind,
                          gather_residuals))
            if self.rerank_backend == "fused":
                tail = (Stage("fused_rerank", DEVICE, exact_fused,
                              device_dispatches=1),)
            else:
                tail = (Stage("device_score:exact", DEVICE, exact,
                              device_dispatches=4),
                        Stage("fuse_topk", DEVICE, fuse,
                              device_dispatches=0))
            return StagePlan(method=method, stages=head + tail,
                             access_stats=access)

        s1_kind = HOST if self.splade_backend == "host" else DEVICE

        def splade_stage(cb):
            # stage-1 cache: per-query rows are batch-composition
            # independent (the PR 2 parity tests pin batched == single
            # per backend), so hits and misses mix freely — only the
            # missed rows are dispatched, then scattered back in place.
            keys = self._stage1_ctx_keys(cb)
            if keys is None:
                pids_b, s_scores = self.run_splade_batch(
                    list(cb.term_ids), list(cb.term_weights), p.first_k,
                    _record=False)      # both backends return host arrays
                return cb.with_state(pids_b=pids_b, s_scores=s_scores)
            rows = [None if k_ is None else self._caches.stage1.get(k_)
                    for k_ in keys]
            miss = [i for i, r in enumerate(rows) if r is None]
            self.pipeline_stats.counter("cache_stage1_hits",
                                        len(rows) - len(miss))
            self.pipeline_stats.counter("cache_stage1_misses", len(miss))
            if miss:
                pids_m, scores_m = self.run_splade_batch(
                    [cb.term_ids[i] for i in miss],
                    [cb.term_weights[i] for i in miss], p.first_k,
                    _record=False)
                gen = self.index_generation
                for j, i in enumerate(miss):
                    rows[i] = (pids_m[j], scores_m[j])
                    if keys[i] is not None:
                        self._caches.stage1.put(
                            keys[i], freeze(pids_m[j], scores_m[j]), gen)
            pids_b = np.stack([r[0] for r in rows])
            s_scores = np.stack([r[1] for r in rows])
            return cb.with_state(pids_b=pids_b, s_scores=s_scores)

        if method == "splade":
            def fuse_splade(cb):
                s = cb.state
                return cb.evolve(pids=s["pids_b"][:, :cb.k],
                                 scores=s["s_scores"][:, :cb.k])

            stages = (Stage("splade_stage1", s1_kind, splade_stage),
                      Stage("fuse_splade", HOST, fuse_splade))
            return StagePlan(method=method, stages=stages,
                             access_stats=access)

        # rerank / hybrid: SPLADE candidates → residual gather → exact
        # MaxSim rescoring (+ α-fusion) → top-k
        def gather(cb):
            s = cb.state
            q, q_valid = pad_query_batch_host(cb.q_embs)
            B, q, q_valid, pids_p = _pad_batch_rows(
                q, q_valid, np.asarray(s["pids_b"]))
            if dr:
                codes, packed, valid = searcher.gather_tokens_batch(pids_p)
            else:
                codes, packed, valid = searcher._dedup_gather(
                    pids_p, codes_only=False)
            return cb.with_state(q=q, q_valid=q_valid, B=B, pids_p=pids_p,
                                 g_codes=codes, g_packed=packed,
                                 g_valid=valid)

        def score(cb):
            s = cb.state
            # dispatch only — the returned values are lazy device
            # arrays; the fuse stage's first host touch waits for them
            # with the GIL released, so the device executes batch N
            # while the host worker gathers batch N+1
            lazy = searcher.score_gathered_lazy(
                jnp.asarray(s["q"]), jnp.asarray(s["q_valid"]),
                jnp.asarray(s["g_codes"]), jnp.asarray(s["g_packed"]),
                jnp.asarray(s["g_valid"]), s["pids_p"])[:s["B"]]
            if method == "hybrid":
                # α-fusion is a jitted dispatch → it belongs to the
                # device stage, not the host-side fuse
                mask = s["pids_b"] >= 0
                final = hybrid_mod.hybrid_scores(
                    jnp.asarray(s["s_scores"]), lazy,
                    jnp.asarray(mask), alpha=jnp.asarray(cb.alphas),
                    normalizer=p.normalizer)
                return cb.with_state(final_dev=final)
            return cb.with_state(c_scores_dev=lazy)

        def fuse_rerank(cb):
            s = cb.state
            pids_b = s["pids_b"]
            if method == "rerank":
                c_scores = np.asarray(s["c_scores_dev"])   # device sync
                final = np.where(pids_b >= 0, c_scores, -np.inf)
            else:
                final = np.asarray(s["final_dev"])         # device sync
            order = np.argsort(-final, axis=1, kind="stable")[:, :cb.k]
            sorted_final = np.take_along_axis(final, order, axis=1)
            out_pids = np.where(
                sorted_final > -np.inf,
                np.take_along_axis(pids_b, order, axis=1), -1)
            return cb.evolve(pids=out_pids, scores=sorted_final)

        def score_fused(cb):
            # the whole stage-4 tail — exact scoring, masking, (hybrid)
            # α-fusion and top-k selection — as ONE lazy device
            # dispatch; cand_mask comes from host numpy so nothing else
            # touches the device here
            s = cb.state
            cand_mask = s["pids_p"] >= 0
            if method == "hybrid":
                top = searcher.fused_hybrid_topk_gathered(
                    jnp.asarray(s["q"]), jnp.asarray(s["q_valid"]),
                    jnp.asarray(s["g_codes"]), jnp.asarray(s["g_packed"]),
                    jnp.asarray(s["g_valid"]), cand_mask, s["s_scores"],
                    cb.alphas, cb.k, s["B"], p.normalizer)
            else:
                top = searcher.fused_topk_gathered(
                    jnp.asarray(s["q"]), jnp.asarray(s["q_valid"]),
                    jnp.asarray(s["g_codes"]), jnp.asarray(s["g_packed"]),
                    jnp.asarray(s["g_valid"]), cand_mask, cb.k)
            return cb.with_state(top_s=top[0], top_i=top[1])

        def fuse_fused(cb):
            # close the async window: sync the (already-selected) top-k
            # and map candidate-axis indices to pids — no argsort, no
            # extra dispatches. Width is min(k, first_k), exactly the
            # split tail's contract.
            s = cb.state
            top_s = np.asarray(s["top_s"])[:s["B"]]    # device sync
            top_i = np.asarray(s["top_i"])[:s["B"]]
            out_pids = np.where(
                top_s > -np.inf,
                np.take_along_axis(np.asarray(s["pids_b"]),
                                   np.clip(top_i, 0, None).astype(np.int64),
                                   axis=1), -1)
            return cb.evolve(pids=out_pids, scores=top_s)

        # score opens the async window (its dispatch returns lazy device
        # values); fuse closes it (first host touch blocks). The
        # single-worker scheduler parks a batch between the two while it
        # runs the next batch's host stages — and fuse is DEVICE-kind so
        # that in threaded mode the sync also stays off the gather
        # worker. The fused backend keeps the identical two-stage
        # async shape (so pipeline overlap is preserved) but its dispatch
        # stage launches ONE device computation instead of 3-4 and its
        # sync stage launches none.
        if self.rerank_backend == "fused":
            tail = (Stage("fused_rerank", DEVICE, score_fused,
                          opens_async=True, device_dispatches=1),
                    Stage("fused_rerank:sync", DEVICE, fuse_fused,
                          closes_async=True, device_dispatches=0))
        else:
            tail = (Stage("device_score:maxsim", DEVICE, score,
                          opens_async=True,
                          device_dispatches=4 if method == "hybrid" else 3),
                    Stage("fuse_topk", DEVICE, fuse_rerank,
                          closes_async=True, device_dispatches=0))
        stages = (Stage("splade_stage1", s1_kind, splade_stage),
                  Stage("host_gather:residuals", gather_kind,
                        gather)) + tail
        return StagePlan(method=method, stages=stages, access_stats=access)

    # ------------------------------------------------------------------
    def search_batch(self, method, q_embs=None, term_ids=None,
                     term_weights=None, alpha=None, k: Optional[int] = None,
                     ctxs=None):
        """Cross-query batched retrieval over any of the four methods.

        ``method``: one method name for the whole batch, or a sequence of
        per-query names (mixed batches are grouped and each group runs
        batched). ``q_embs``/``term_ids``/``term_weights``: per-query
        sequences (ragged lengths fine). ``alpha``: scalar, per-query
        sequence, or None (per-params default). Returns
        (pids (B, k), scores (B, k)) matching per-query :meth:`search`.

        Legacy wrapper over :meth:`search_batch_ctx`: the typed outcome
        is folded back into the thread-local degraded note for callers
        that still read ``last_missing_shards``.
        """
        pids, scores, outcome = self.search_batch_ctx(
            method, q_embs=q_embs, term_ids=term_ids,
            term_weights=term_weights, alpha=alpha, k=k, ctxs=ctxs)
        self._note_degraded(outcome.missing_shards)
        return pids, scores

    def search_batch_ctx(self, method, q_embs=None, term_ids=None,
                         term_weights=None, alpha=None,
                         k: Optional[int] = None, ctxs=None):
        """:meth:`search_batch` with a typed outcome: returns
        ``(pids, scores, BatchOutcome)``. The outcome carries what the
        thread-local side channel used to (missing shards under degraded
        shard groups), returned to the caller instead of stashed.

        ``ctxs``: optional per-query
        :class:`~repro.serving.context.RequestContext` sequence — when a
        cache hierarchy is attached, plan stages consult each context's
        ``stage1_key`` for the candidate-gather cache.

        Runs the method's compiled :class:`StagePlan` synchronously —
        the ``pipeline_depth=1`` path of the stage-graph executor.

        With a live index attached the whole batch holds the compaction
        gate's read side: queries proceed concurrently (and re-entrantly
        — the mixed-batch path recurses) and only the atomic generation
        swap excludes them.
        """
        gate = getattr(self.live, "gate", None)
        if gate is None:
            return self._search_batch_ctx_impl(method, q_embs, term_ids,
                                               term_weights, alpha, k, ctxs)
        with gate.read():
            return self._search_batch_ctx_impl(method, q_embs, term_ids,
                                               term_weights, alpha, k, ctxs)

    def _search_batch_ctx_impl(self, method, q_embs, term_ids,
                               term_weights, alpha, k, ctxs):
        p = self.params
        k = p.k if k is None else k
        n = len(q_embs) if q_embs is not None else len(term_ids)

        if not isinstance(method, str):
            methods = list(method)
            if len(set(methods)) > 1:
                return self._search_batch_mixed(methods, q_embs, term_ids,
                                                term_weights, alpha, k,
                                                ctxs)
            method = methods[0]

        alphas = self._alpha_array(alpha, n)
        live = self.live
        if live is not None and live.dirty and self._live_inline:
            return self._search_batch_live(live, method, q_embs, term_ids,
                                           term_weights, alphas, k)
        cb = self.build_batch(method, q_embs, term_ids, term_weights,
                              alphas, k, n, ctxs=ctxs)
        cb = self.compile_plan(method).run(cb, stats=self.pipeline_stats)
        return cb.pids, cb.scores, BatchOutcome(
            missing_shards=tuple(cb.state.get("missing_shards", ())))

    # ------------------------------------------------------------------
    # live (mutable) index: overlay serving, mutations, compaction
    # ------------------------------------------------------------------
    # Unsharded retrievers serve a dirty live state through the inline
    # overlay path below; sharded groups instead inject the live state
    # into their merge/fuse bodies (set False there) so per-shard plans
    # stay frozen.
    _live_inline = True

    def enable_live(self):
        """Attach a :class:`~repro.index.live.LiveIndexState` and return
        it. Idempotent. Until the first mutation the state is clean and
        every serve path is byte-for-byte the frozen one."""
        if self.live is not None:
            return self.live
        if self.searcher.device_resident:
            raise ValueError("live index requires the host (mmap) tier; "
                             "device_resident pools are frozen")
        from repro.index.live import LiveIndexState
        self.live = LiveIndexState(self.searcher.index, self.splade)
        return self.live

    def _require_live(self):
        if self.live is None:
            raise RuntimeError("live index not enabled (enable_live / "
                               "--live)")
        return self.live

    def live_upsert(self, doc_emb, term_ids, term_weights,
                    doc_len=None) -> int:
        """Append a document to the delta segment → its global pid.
        Bumps the index generation so result/stage-1 caches invalidate."""
        pid = self._require_live().upsert(doc_emb, term_ids, term_weights,
                                          doc_len)
        self.bump_index_generation()
        return pid

    def live_delete(self, gpid: int) -> bool:
        """Tombstone a global pid; True if it was live before."""
        ok = self._require_live().delete(gpid)
        if ok:
            self.bump_index_generation()
        return ok

    def live_stats(self) -> dict:
        live = self.live
        if live is None:
            return {}
        out = live.stats()
        out["generation"] = self.index_generation
        return out

    def compact_live(self):
        """Merge the delta prefix into a new on-disk index generation
        and atomically swap the serve handles.

        The build runs entirely off-gate (queries keep flowing against
        base+delta); only the final handle swap takes the write gate,
        drains in-flight readers, and bumps the generation. Global pids
        are stable across the swap — delta doc ``j`` simply becomes base
        doc ``base_n + j`` — so tombstones and cached client-side pids
        stay valid."""
        live = self._require_live()
        n_take = live.snapshot_delta()
        if n_take == 0:
            return None
        from repro.index import live as live_mod
        idx = self.searcher.index
        gen = self.index_generation + 1
        col_dir = idx.path.with_name(f"{idx.path.name}.g{gen}")
        spl_dir = idx.path.with_name(f"splade.g{gen}")
        live_mod.compact_colbert_dir(idx, live, n_take, col_dir)
        live_mod.compact_splade_dir(self.splade, live, n_take, spl_dir)
        from repro.index.builder import ColBERTIndex
        new_index = ColBERTIndex(col_dir, mode=idx.store.mode)
        new_searcher = PLAIDSearcher(new_index, self.searcher.params,
                                     device_resident=False)
        new_splade = SpladeIndex.load(spl_dir)
        with live.gate.write():
            self.splade = new_splade
            self.searcher = new_searcher
            with self._lock:
                self._plans.clear()
                self._splade_device = None
            live.rebase(n_take)
            self.bump_index_generation()
        return {"compacted": n_take, "colbert_dir": str(col_dir),
                "splade_dir": str(spl_dir)}

    def _live_exact(self, live, q, q_valid, pids_p: np.ndarray):
        """Exact scores (host (Bp, C) f32) for a pid matrix that may mix
        base and delta pids. Each origin is scored by its own gather +
        decompress-MaxSim dispatch and scattered positionally — per-
        candidate scores are independent, so the stitched matrix is
        bitwise what one dispatch over a unified index would produce."""
        pids_p = np.asarray(pids_p)
        delta_mask = pids_p >= live.base_n
        base_pids = np.where(delta_mask, -1, pids_p)
        codes, packed, valid = self.searcher._dedup_gather(
            base_pids, codes_only=False)
        base_scores = np.asarray(self.searcher.score_gathered_lazy(
            jnp.asarray(q), jnp.asarray(q_valid), jnp.asarray(codes),
            jnp.asarray(packed), jnp.asarray(valid), base_pids))
        if delta_mask.any():
            delta_pids = np.where(delta_mask, pids_p, -1)
            d_scores = live.exact_scores(q, q_valid, delta_pids)
            return np.where(delta_mask, d_scores,
                            base_scores).astype(np.float32)
        return base_scores.astype(np.float32)

    def _search_batch_live(self, live, method, q_embs, term_ids,
                           term_weights, alphas, k: int):
        """Overlay serving for a dirty live state: compose the same
        stage primitives the frozen plans run — base index scoring plus
        the delta segment, tombstones filtered at every merge — without
        touching the compiled plans (which stay bitwise-frozen for the
        inert case). Always the split stage-4 tail (bitwise-identical to
        the fused one per the PR 8 parity contract)."""
        from repro.core import plaid as plaid_mod
        from repro.core.sharded import merge_topk
        p = self.params
        searcher = self.searcher
        outcome = BatchOutcome()

        if method in ("splade", "rerank", "hybrid"):
            pids_b, s_scores = self.run_splade_batch(
                list(term_ids), list(term_weights), p.first_k)
            if method == "splade":
                return pids_b[:, :k], s_scores[:, :k], outcome
            q, q_valid = pad_query_batch_host(q_embs)
            B, q, q_valid, pids_p = _pad_batch_rows(
                q, q_valid, np.asarray(pids_b))
            c_scores = self._live_exact(live, q, q_valid, pids_p)[:B]
            if method == "rerank":
                final = np.where(pids_b >= 0, c_scores, -np.inf)
            else:
                mask = pids_b >= 0
                final = np.asarray(hybrid_mod.hybrid_scores(
                    jnp.asarray(s_scores), jnp.asarray(c_scores),
                    jnp.asarray(mask), alpha=jnp.asarray(alphas),
                    normalizer=p.normalizer))
            order = np.argsort(-final, axis=1, kind="stable")[:, :k]
            sorted_final = np.take_along_axis(final, order, axis=1)
            out_pids = np.where(sorted_final > -np.inf,
                                np.take_along_axis(pids_b, order, axis=1),
                                -1)
            return out_pids, sorted_final, outcome

        if method != "colbert":
            raise ValueError(method)
        sp = searcher.params
        # stages 1-2 on the frozen base, mirroring probe_batch (exposed
        # here because the overlay needs the probed cids for the delta
        # IVF, which probe_batch does not return)
        q, q_valid = plaid_mod.pad_query_batch(q_embs)
        B, q, q_valid = _pad_batch_rows(q, q_valid)
        scores_c, cids = plaid_mod.stage1_centroid_probe_batch(
            q, q_valid, searcher.centroids, sp.nprobe)
        cand = plaid_mod.stage2_candidates_batch(
            searcher.ivf_padded, cids, sp.candidate_cap)
        cand_np = np.asarray(cand)
        n_real = (cand_np[:B] >= 0).sum(axis=1)

        codes, _, valid = searcher._dedup_gather(cand_np, codes_only=True)
        approx = plaid_mod.stage3_approx_score_batch(
            scores_c, jnp.asarray(codes), jnp.asarray(valid), q_valid)
        approx_np = np.asarray(jnp.where(cand >= 0, approx, -jnp.inf))

        # tombstoned base candidates drop out pre-merge (pid -1 / -inf,
        # exactly how padded candidate slots already behave)
        tomb = live.is_tombstoned(np.clip(cand_np, 0, None)) & (cand_np >= 0)
        base_cand = np.where(tomb, -1, cand_np).astype(np.int64)
        approx_np = np.where(tomb, -np.inf, approx_np).astype(np.float32)

        # delta candidates from the probed centroids' delta postings
        d_lists = live.delta_candidates(np.asarray(cids))
        W = max(1, max((len(x) for x in d_lists), default=0))
        d_mat = np.full((cand_np.shape[0], W), -1, np.int64)
        for b, arr in enumerate(d_lists):
            d_mat[b, :len(arr)] = arr
        d_approx = live.approx_scores(scores_c, q_valid, d_mat)

        ndocs = min(sp.ndocs, sp.candidate_cap)
        final_np, _ = merge_topk(
            np.concatenate([base_cand, d_mat], axis=1),
            np.concatenate([approx_np, d_approx], axis=1), ndocs)

        exact = self._live_exact(live, q, q_valid, final_np)
        out_pids, out_scores = searcher.finalize_topk(
            jnp.asarray(exact), jnp.asarray(final_np), B, k)
        return out_pids, out_scores, outcome

    # ------------------------------------------------------------------
    # degraded-answer bookkeeping (sharded process groups only; the
    # in-process backends never produce a ``missing_shards`` state)
    # ------------------------------------------------------------------
    @property
    def _degraded_tls(self):
        # lazy: the sharded subclasses build themselves without calling
        # this __init__
        return self.__dict__.setdefault("_degraded_tls_obj",
                                        threading.local())

    def _note_degraded(self, missing):
        """Record (per serving thread) that the batch just searched was
        answered without these shards; mixed-method batches union their
        groups' notes."""
        if not missing:
            return
        tls = self._degraded_tls
        prior = getattr(tls, "missing", ())
        if not prior:
            self.pipeline_stats.counter("degraded_batches")
        tls.missing = tuple(sorted(set(prior) | set(missing)))

    def last_missing_shards(self) -> tuple:
        """Missing-shard ids of this thread's last ``search_batch``
        (empty when it was a full answer); reading clears the note."""
        tls = self._degraded_tls
        out = getattr(tls, "missing", ())
        tls.missing = ()
        return out

    def _alpha_array(self, alpha, n: int) -> np.ndarray:
        if alpha is None:
            return np.full(n, self.params.alpha, np.float32)
        if np.ndim(alpha) == 0:
            return np.full(n, float(alpha), np.float32)
        return np.asarray([self.params.alpha if a is None else float(a)
                           for a in alpha], np.float32)

    @staticmethod
    def scatter_group(out_pids, out_scores, idx, pids, scores):
        """Scatter one method group's results back into request order.
        splade-first groups return min(k, first_k) columns — they fill
        the prefix, leaving the (-1, -inf) tail as padding. Shared with
        the pipelined engine so mixed-batch semantics cannot drift."""
        w = pids.shape[1]
        out_pids[idx, :w] = pids
        out_scores[idx, :w] = scores

    def _search_batch_mixed(self, methods, q_embs, term_ids, term_weights,
                            alpha, k: int, ctxs=None):
        """Group a mixed-method batch by method, run each group batched,
        and scatter results back into request order. Group outcomes are
        merged (missing-shard union across groups)."""
        n = len(methods)
        alphas = self._alpha_array(alpha, n)
        out_pids = np.full((n, k), -1, np.int64)
        out_scores = np.full((n, k), -np.inf, np.float32)
        outcome = BatchOutcome()
        for m in dict.fromkeys(methods):
            idx = [i for i, mi in enumerate(methods) if mi == m]
            pick = (lambda seq: None if seq is None
                    else [seq[i] for i in idx])
            pids, scores, out = self.search_batch_ctx(
                m, q_embs=pick(q_embs), term_ids=pick(term_ids),
                term_weights=pick(term_weights), alpha=alphas[idx], k=k,
                ctxs=pick(ctxs))
            outcome = outcome.merge(out)
            self.scatter_group(out_pids, out_scores, idx, pids, scores)
        return out_pids, out_scores, outcome
