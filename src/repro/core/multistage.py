"""ColBERT-serve's multi-stage retrieval pipeline.

Four systems, exactly as the paper's evaluation defines them:

  * ``colbert``  — full PLAID end-to-end (in-memory or MMAP per store mode)
  * ``splade``   — SPLADEv2 w/ PISA-style impact index only
  * ``rerank``   — SPLADE top-``first_k`` → MMAP ColBERT exact rescoring
  * ``hybrid``   — rerank + α-interpolated z-normed score fusion
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import hybrid as hybrid_mod
from repro.core.plaid import PLAIDSearcher
from repro.index.splade_index import SpladeIndex


@dataclasses.dataclass(frozen=True)
class MultiStageParams:
    first_k: int = 200            # SPLADE candidates (paper: top-200)
    k: int = 100                  # final depth
    alpha: float = 0.3            # paper's MS MARCO-tuned value
    normalizer: str = "znorm"


class MultiStageRetriever:
    def __init__(self, splade_index: SpladeIndex, searcher: PLAIDSearcher,
                 params: MultiStageParams = MultiStageParams()):
        self.splade = splade_index
        self.searcher = searcher
        self.params = params

    # ------------------------------------------------------------------
    def run_splade(self, term_ids, term_weights, k: Optional[int] = None):
        return self.splade.score_host(np.asarray(term_ids),
                                      np.asarray(term_weights),
                                      k or self.params.first_k)

    # ------------------------------------------------------------------
    def search(self, method: str, q_emb=None, term_ids=None,
               term_weights=None, alpha: Optional[float] = None,
               k: Optional[int] = None):
        """Returns (pids (k,), scores (k,)), -1 padded, descending."""
        p = self.params
        k = k or p.k
        alpha = p.alpha if alpha is None else alpha

        if method == "colbert":
            pids, scores, _ = self.searcher.search(q_emb, k=k)
            return pids, scores

        pids, s_scores = self.run_splade(term_ids, term_weights, p.first_k)
        if method == "splade":
            return pids[:k], s_scores[:k]

        c_scores = self.searcher.rerank(q_emb, pids)
        mask = pids >= 0
        if method == "rerank":
            final = np.where(mask, c_scores, -np.inf)
        elif method == "hybrid":
            final = np.asarray(hybrid_mod.hybrid_scores(
                jnp.asarray(s_scores), jnp.asarray(c_scores),
                jnp.asarray(mask), alpha=alpha, normalizer=p.normalizer))
        else:
            raise ValueError(method)

        order = np.argsort(-final, kind="stable")[:k]
        out_pids = np.where(final[order] > -np.inf, pids[order], -1)
        return out_pids, final[order]
