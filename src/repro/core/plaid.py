"""PLAID multi-stage search over the compressed ColBERTv2 index.

Stages (Santhanam et al., CIKM'22):
  1. centroid scoring:   S_c = Q · C^T, top-``nprobe`` centroids/q-token
  2. candidate generation from the IVF
  3. approximate scoring by centroid interaction (codes only — cheap,
     *no residual access*)
  4. residual decompression + exact MaxSim for the surviving ``ndocs``

The class orchestrates jitted device stages with host gathers through
the PagedStore (mmap tier), mirroring the paper's Python↔C++ split.
``device_resident=True`` instead keeps the whole pool in device memory
and exposes a single jitted ``serve_step`` — that path is what the
multi-pod dry-run lowers, with the pool sharded over the 'model' axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.core import hybrid as hybrid_mod
from repro.index.builder import ColBERTIndex
from repro.index.residual import unpack_codes
from repro.kernels.decompress_maxsim.ops import decompress_maxsim_scores_batch
from repro.kernels.fused_rerank.ops import fused_rerank_topk_batch
from repro.models.colbert import maxsim


@dataclasses.dataclass(frozen=True)
class PlaidParams:
    nprobe: int = 4
    candidate_cap: int = 4096    # max candidate pids after stage 2
    ndocs: int = 256             # survivors entering exact scoring
    k: int = 100                 # final results


# --------------------------------------------------------------------------
# jitted stage kernels (shapes static per index)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nprobe",))
def stage1_centroid_probe(q_emb, centroids, nprobe: int):
    """q_emb (Lq, d), centroids (K, d) → (scores_c (Lq, K), top cids)."""
    s = jnp.einsum("qd,kd->qk", q_emb, centroids,
                   preferred_element_type=jnp.float32)
    _, cids = jax.lax.top_k(s, nprobe)
    return s, cids.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cap",))
def stage2_candidates(ivf_padded, cids, cap: int):
    """ivf_padded (K, P) int32 (−1 fill); cids (Lq, nprobe) →
    unique candidate pids (cap,) (−1 fill)."""
    cand = ivf_padded[cids.reshape(-1)].reshape(-1)      # (Lq*nprobe*P,)
    # unique with static size; -1 fill sorts first so drop via where
    uniq = jnp.unique(cand, size=cap + 1, fill_value=-1)
    uniq = jnp.where(uniq >= 0, uniq, -1)
    # compact: move -1s to the back (sort by (is_pad, value))
    order = jnp.argsort(jnp.where(uniq >= 0, 0, 1), stable=True)
    return uniq[order][:cap]


@jax.jit
def stage3_approx_score(scores_c, cand_codes, cand_valid, q_valid=None):
    """Centroid-interaction approximation.

    scores_c: (Lq, K); cand_codes: (C, Ld) int32 centroid ids;
    cand_valid: (C, Ld) → approx scores (C,)."""
    s = scores_c[:, cand_codes]                  # (Lq, C, Ld)
    s = jnp.where(cand_valid[None], s, -1e30)
    per_q = jnp.max(s, axis=-1)                  # (Lq, C)
    per_q = jnp.where(per_q <= -1e29, 0.0, per_q)
    if q_valid is not None:
        per_q = per_q * q_valid[:, None]
    return jnp.sum(per_q, axis=0)                # (C,)


@functools.partial(jax.jit, static_argnames=("nbits", "k", "b",
                                             "normalizer", "impl"))
def fused_hybrid_tail(q, packed, cids, valid, cand_mask, centroids,
                      bucket_weights, q_valid, s_scores, alphas, *,
                      nbits: int, k: int, b: int, normalizer: str,
                      impl: str = "auto"):
    """Fused stage-4 tail for the hybrid method: decompress + MaxSim
    (the fused scoring kernel on TPU), α-interpolated z-normed fusion
    with the stage-1 scores, and the per-query top-k — ONE dispatch.

    Hybrid cannot take the top-k-only ``fused_rerank`` kernel end-to-end
    because the normaliser needs per-query statistics over the *full*
    candidate list; the (b, C) exact-score tensor is tiny (C = first_k),
    so the win here is folding masking + fusion + selection into the
    scoring dispatch — no host argsort, no intermediate syncs. Scoring
    runs on the padded ``Bp`` rows and slices to the ``b`` real ones
    exactly like the split path, so results stay bitwise-identical.
    """
    c = decompress_maxsim_scores_batch(
        q, packed, cids, valid, centroids, bucket_weights, nbits=nbits,
        q_valid=q_valid, impl=impl)
    c = jnp.where(cand_mask, c, -jnp.inf)[:b]
    final = hybrid_mod.hybrid_scores(s_scores, c, cand_mask[:b],
                                     alpha=alphas, normalizer=normalizer)
    return jax.lax.top_k(final, k)


@functools.partial(jax.jit, static_argnames=("nbits",))
def stage4_exact_score(q_emb, packed, cids, valid, centroids,
                       bucket_weights, nbits: int):
    """Decompress-and-MaxSim: packed (C, Ld, pd) uint8, cids (C, Ld)."""
    codes = unpack_codes(packed, nbits)
    emb = centroids[cids] + bucket_weights[codes.astype(jnp.int32)]
    emb = emb * valid[..., None]
    return maxsim(q_emb, emb, valid)


# --------------------------------------------------------------------------
# batched stage kernels (cross-query micro-batches)
# --------------------------------------------------------------------------

def pad_query_batch_host(q_embs, lq_multiple: int = 4):
    """Numpy-only variant of :func:`pad_query_batch` (no device
    transfer) — for host-bound pipeline stages, which must not touch
    the device client while a device stage is dispatching."""
    arrs = [np.asarray(qe, np.float32) for qe in q_embs]
    d = arrs[0].shape[-1]
    lq_pad = -(-max(a.shape[0] for a in arrs) // lq_multiple) * lq_multiple
    q = np.zeros((len(arrs), lq_pad, d), np.float32)
    valid = np.zeros((len(arrs), lq_pad), bool)
    for i, a in enumerate(arrs):
        q[i, :a.shape[0]] = a
        valid[i, :a.shape[0]] = True
    return q, valid


def pad_query_batch(q_embs, lq_multiple: int = 4):
    """Stack ragged queries. q_embs: sequence of (Lq_i, d) arrays or an
    already-stacked (B, Lq, d) array → ((B, Lq_pad, d) f32 zero-padded,
    (B, Lq_pad) bool validity).

    ``Lq_pad`` rounds the longest query up to ``lq_multiple`` so ragged
    batches reuse a small set of compiled shapes instead of recompiling
    the batched stages per distinct length."""
    q, valid = pad_query_batch_host(q_embs, lq_multiple)
    return jnp.asarray(q), jnp.asarray(valid)


def _pad_batch_rows(q, q_valid, *extra):
    """Pad the batch dim to the next power of two by replicating the
    last real row (of ``q``/``q_valid`` and each array in ``extra``), so
    compiled batched stages are reused across nearby batch sizes and the
    padding rows add no new pids to the deduplicated host gathers.
    Returns (B_real, q, q_valid, *extra)."""
    B = q.shape[0]
    Bp = _next_pow2(B)
    if Bp == B:
        return (B, q, q_valid) + extra
    reps = Bp - B

    def pad(x):
        if isinstance(x, np.ndarray):
            return np.concatenate([x, np.repeat(x[-1:], reps, axis=0)],
                                  axis=0)
        return jnp.concatenate([x, jnp.repeat(x[-1:], reps, axis=0)],
                               axis=0)

    return (B, pad(q), pad(q_valid)) + tuple(pad(x) for x in extra)


@functools.partial(jax.jit, static_argnames=("nprobe",))
def stage1_centroid_probe_batch(q_emb, q_valid, centroids, nprobe: int):
    """q_emb (B, Lq, d), q_valid (B, Lq), centroids (K, d) →
    (scores_c (B, Lq, K), cids (B, Lq, nprobe))."""
    s = jnp.einsum("bqd,kd->bqk", q_emb, centroids,
                   preferred_element_type=jnp.float32)
    _, cids = jax.lax.top_k(s, nprobe)
    # padded query tokens must not widen the candidate set: replicate the
    # first (always-real) token's probes, which add nothing new
    cids = jnp.where(q_valid[..., None], cids, cids[:, :1, :])
    return s, cids.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cap",))
def stage2_candidates_batch(ivf_padded, cids, cap: int):
    """cids (B, Lq, nprobe) → per-query unique candidates (B, cap)."""
    return jax.vmap(lambda c: stage2_candidates(ivf_padded, c, cap))(cids)


@jax.jit
def stage3_approx_score_batch(scores_c, cand_codes, cand_valid, q_valid):
    """Batched centroid-interaction approximation: scores_c (B, Lq, K),
    cand_codes/cand_valid (B, C, Ld), q_valid (B, Lq) → (B, C)."""
    return jax.vmap(stage3_approx_score)(scores_c, cand_codes, cand_valid,
                                         q_valid.astype(jnp.float32))


# --------------------------------------------------------------------------
# Orchestrator
# --------------------------------------------------------------------------

class PLAIDSearcher:
    def __init__(self, index: ColBERTIndex, params: PlaidParams = PlaidParams(),
                 device_resident: bool = False, ivf_pad: Optional[int] = None):
        self.index = index
        self.params = params
        self.centroids = jnp.asarray(index.centroids)
        self.bucket_weights = jnp.asarray(index.bucket_weights)
        self.ivf_padded = jnp.asarray(index.ivf.as_padded(ivf_pad))
        self.device_resident = device_resident
        if device_resident:
            # whole pool in device memory (the in-memory ColBERTv2 baseline
            # or the TPU serve path with the pool sharded over 'model')
            self.dev_codes = jnp.asarray(np.asarray(index.store.codes))
            self.dev_residuals = jnp.asarray(np.asarray(index.store.residuals))
            self.dev_offsets = jnp.asarray(index.doc_offsets)
            self.dev_doclens = jnp.asarray(index.doclens)

    # -- full PLAID (stages 1-4) ------------------------------------------
    def search(self, q_emb: np.ndarray, k: Optional[int] = None):
        """q_emb: (Lq, dim). Returns (pids (k,), scores (k,)) desc."""
        p = self.params
        k = p.k if k is None else k
        q = jnp.asarray(q_emb)
        scores_c, cids = stage1_centroid_probe(q, self.centroids, p.nprobe)
        cand = stage2_candidates(self.ivf_padded, cids, p.candidate_cap)

        cand_np = np.asarray(cand)
        n_real = int((cand_np >= 0).sum())
        if self.device_resident:
            codes, _, valid = self._gather_device(cand)
        else:
            # codes-only gather: the approximate stage must not fault
            # residual mmap pages (the paper's access-minimisation claim)
            codes_np, valid_np = self.index.gather_doc_codes(cand_np)
            codes, valid = jnp.asarray(codes_np), jnp.asarray(valid_np)

        approx = stage3_approx_score(scores_c, codes, valid)
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        ndocs = min(p.ndocs, p.candidate_cap)
        _, keep = jax.lax.top_k(approx, ndocs)
        final_pids = cand[keep]

        if self.device_resident:
            f_codes, f_packed, f_valid = self._gather_device(final_pids)
        else:
            # Stage 4 is the only residual access — this is where the
            # mmap pages get touched.
            c_np, r_np, v_np = self.index.gather_doc_tokens(
                np.asarray(final_pids))
            f_codes, f_packed, f_valid = (jnp.asarray(c_np),
                                          jnp.asarray(r_np),
                                          jnp.asarray(v_np))

        exact = stage4_exact_score(q, f_packed, f_codes, f_valid,
                                   self.centroids, self.bucket_weights,
                                   self.index.nbits)
        exact = jnp.where(final_pids >= 0, exact, -jnp.inf)
        k_eff = min(k, ndocs)
        top_s, idx = jax.lax.top_k(exact, k_eff)
        out_pids = np.full(k, -1, np.int64)
        out_scores = np.full(k, -np.inf, np.float32)
        out_pids[:k_eff] = np.asarray(final_pids[idx])
        out_scores[:k_eff] = np.asarray(top_s)
        return out_pids, out_scores, {"candidates": n_real}

    # -- batched stage pieces (shared by search_batch and the pipeline) ----
    #
    # ``MultiStageRetriever.compile_plan`` wraps these into typed stages
    # (plaid_probe / host_gather / device_score / fuse_topk) and
    # ``search_batch`` composes the exact same functions in the exact
    # same order, so the synchronous and pipelined paths cannot drift.

    def probe_batch(self, q_embs) -> dict:
        """Stages 1-2 (device): pad/stack ragged queries, probe
        centroids, generate per-query unique candidate sets."""
        p = self.params
        q, q_valid = pad_query_batch(q_embs)
        B, q, q_valid = _pad_batch_rows(q, q_valid)
        scores_c, cids = stage1_centroid_probe_batch(q, q_valid,
                                                     self.centroids, p.nprobe)
        cand = stage2_candidates_batch(self.ivf_padded, cids,
                                       p.candidate_cap)       # (Bp, cap)
        return {"B": B, "q": q, "q_valid": q_valid,
                "scores_c": scores_c, "cand": cand}

    def gather_codes_batch(self, cand):
        """Codes-only candidate gather for the approximate stage — the
        host-bound step in mmap mode (never faults a residual page)."""
        if self.device_resident:
            codes, _, valid = self._gather_device_batch(cand)
            return codes, valid
        codes_np, _, valid_np = self._dedup_gather(np.asarray(cand),
                                                   codes_only=True)
        return jnp.asarray(codes_np), jnp.asarray(valid_np)

    def approx_select_batch(self, scores_c, codes, valid, q_valid, cand):
        """Stage 3 (device): centroid-interaction scores → the ``ndocs``
        survivors entering exact scoring."""
        approx = stage3_approx_score_batch(scores_c, codes, valid, q_valid)
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        ndocs = min(self.params.ndocs, self.params.candidate_cap)
        _, keep = jax.lax.top_k(approx, ndocs)
        return jnp.take_along_axis(cand, keep, axis=1)        # (Bp, ndocs)

    def gather_tokens_batch(self, pids):
        """Residual gather (host-bound in mmap mode — the only stage
        that faults residual pages; one deduplicated gather per batch)."""
        if self.device_resident:
            dev_pids = pids if isinstance(pids, jax.Array) \
                else jnp.asarray(pids)
            return self._gather_device_batch(dev_pids)
        c_np, r_np, v_np = self._dedup_gather(np.asarray(pids),
                                              codes_only=False)
        return jnp.asarray(c_np), jnp.asarray(r_np), jnp.asarray(v_np)

    def exact_score_gathered(self, q, q_valid, codes, packed, valid,
                             final_pids):
        """Stage 4 (device): fused decompress + MaxSim over gathered
        candidate tokens; -inf at padded candidate slots."""
        exact = decompress_maxsim_scores_batch(
            q, packed, codes.astype(jnp.int32), valid, self.centroids,
            self.bucket_weights, nbits=self.index.nbits, q_valid=q_valid)
        return jnp.where(final_pids >= 0, exact, -jnp.inf)

    def finalize_topk(self, exact, final_pids, B: int, k: int):
        """Terminal fuse: per-query top-k and (-1, -inf)-padded (B, k)
        host arrays."""
        ndocs = min(self.params.ndocs, self.params.candidate_cap)
        k_eff = min(k, ndocs)
        top_s, idx = jax.lax.top_k(exact, k_eff)
        out_pids = np.full((B, k), -1, np.int64)
        out_scores = np.full((B, k), -np.inf, np.float32)
        out_pids[:, :k_eff] = np.asarray(
            jnp.take_along_axis(final_pids, idx, axis=1))[:B]
        out_scores[:, :k_eff] = np.asarray(top_s)[:B]
        return out_pids, out_scores

    def score_gathered_lazy(self, q, q_valid, codes, packed, valid,
                            pids_p):
        """Rerank scoring over already-gathered tokens, returned as the
        *lazy* device value: the jitted dispatch returns immediately
        (async on every backend, CPU included) and the caller syncs when
        it first touches the result — a GIL-releasing wait, so the
        pipeline's host worker gathers the next micro-batch while the
        device executes this one."""
        scores = decompress_maxsim_scores_batch(
            q, packed, codes.astype(jnp.int32), valid, self.centroids,
            self.bucket_weights, nbits=self.index.nbits, q_valid=q_valid)
        return jnp.where(jnp.asarray(pids_p) >= 0, scores, -jnp.inf)

    def score_gathered_batch(self, q, q_valid, codes, packed, valid,
                             pids_p, B: int):
        """Rerank scoring over already-gathered tokens → host (B, C)
        scores aligned with ``pids_p`` (rows beyond ``B`` dropped)."""
        return np.asarray(self.score_gathered_lazy(
            q, q_valid, codes, packed, valid, pids_p))[:B]

    # -- fused stage-4 tail (rerank_backend="fused") -----------------------
    def fused_topk_gathered(self, q, q_valid, codes, packed, valid,
                            cand_mask, k: int):
        """Fused stage-4 tail: decompress + MaxSim + per-query top-k as
        ONE device dispatch — the tiled ``fused_rerank`` Pallas kernel
        on TPU (no materialised (B, C) scores), the same fused XLA
        computation elsewhere. ``cand_mask``: host (Bp, C) bool
        (``pids >= 0``). Returns *lazy* (scores (Bp, kk), idx (Bp, kk)
        into the candidate axis), kk = min(k, C), selection and tie
        order bitwise-identical to :meth:`exact_score_gathered` +
        ``lax.top_k``."""
        return fused_rerank_topk_batch(
            q, packed, codes.astype(jnp.int32), valid,
            jnp.asarray(cand_mask), self.centroids, self.bucket_weights,
            nbits=self.index.nbits, k=min(k, cand_mask.shape[1]),
            q_valid=q_valid)

    def fused_hybrid_topk_gathered(self, q, q_valid, codes, packed, valid,
                                   cand_mask, s_scores, alphas, k: int,
                                   b: int, normalizer: str):
        """Hybrid fused tail (see :func:`fused_hybrid_tail`): scoring +
        α-fusion + top-k in one dispatch. Returns lazy (scores (b, kk),
        idx (b, kk)), kk = min(k, first_k)."""
        return fused_hybrid_tail(
            q, packed, codes.astype(jnp.int32), valid,
            jnp.asarray(cand_mask), self.centroids, self.bucket_weights,
            q_valid, jnp.asarray(s_scores), jnp.asarray(alphas),
            nbits=self.index.nbits, k=min(k, cand_mask.shape[1]), b=b,
            normalizer=normalizer)

    def finalize_topk_fused(self, top_s, top_i, final_np, B: int, k: int):
        """Terminal formatting for the fused tail: map candidate-axis
        indices back to pids and pad to the (B, k) (-1, -inf) contract —
        the fused counterpart of :meth:`finalize_topk`, minus its
        ``lax.top_k``/``take_along_axis`` dispatches (selection already
        happened inside the fused kernel)."""
        kk = top_i.shape[1]
        out_pids = np.full((B, k), -1, np.int64)
        out_scores = np.full((B, k), -np.inf, np.float32)
        s_np = np.asarray(top_s)[:B]
        i_np = np.asarray(top_i)[:B]
        out_pids[:, :kk] = np.take_along_axis(
            final_np[:B], np.clip(i_np, 0, None).astype(np.int64), axis=1)
        out_pids[:, :kk][i_np < 0] = -1
        out_scores[:, :kk] = s_np
        return out_pids, out_scores

    # -- batched full PLAID (stages 1-4 over a query micro-batch) ----------
    def search_batch(self, q_embs, k: Optional[int] = None):
        """Cross-query batched PLAID. q_embs: sequence of (Lq_i, dim)
        arrays (ragged lengths fine) or a stacked (B, Lq, dim) array.
        Returns (pids (B, k), scores (B, k), aux list) — per-query
        results identical to :meth:`search` within fp tolerance.

        Host candidate gathers are deduplicated across the batch, so
        co-batched queries share mmap page touches; device stages run on
        stacked (B, ...) inputs in a single dispatch each."""
        k = self.params.k if k is None else k
        st = self.probe_batch(q_embs)
        cand_np = np.asarray(st["cand"])
        n_real = (cand_np[:st["B"]] >= 0).sum(axis=1)
        codes, valid = self.gather_codes_batch(st["cand"])
        final_pids = self.approx_select_batch(st["scores_c"], codes, valid,
                                              st["q_valid"], st["cand"])
        f_codes, f_packed, f_valid = self.gather_tokens_batch(final_pids)
        exact = self.exact_score_gathered(st["q"], st["q_valid"], f_codes,
                                          f_packed, f_valid, final_pids)
        out_pids, out_scores = self.finalize_topk(exact, final_pids,
                                                  st["B"], k)
        return out_pids, out_scores, [{"candidates": int(n)} for n in n_real]

    # -- rerank-only (stage 4 on external candidates) ----------------------
    def rerank(self, q_emb: np.ndarray, pids: np.ndarray):
        """Exact MaxSim for given candidates (the paper's Rerank path).
        pids: (C,) (−1 pad). Returns scores (C,) aligned with pids."""
        q = jnp.asarray(q_emb)
        if self.device_resident:
            codes, packed, valid = self._gather_device(jnp.asarray(pids))
        else:
            c_np, r_np, v_np = self.index.gather_doc_tokens(np.asarray(pids))
            codes, packed, valid = (jnp.asarray(c_np), jnp.asarray(r_np),
                                    jnp.asarray(v_np))
        scores = stage4_exact_score(q, packed, codes, valid, self.centroids,
                                    self.bucket_weights, self.index.nbits)
        return np.asarray(jnp.where(jnp.asarray(pids) >= 0, scores, -jnp.inf))

    # -- batched rerank (stage 4 over a query micro-batch) -----------------
    def rerank_batch(self, q_embs, pids: np.ndarray):
        """Exact MaxSim for per-query candidate lists. q_embs: sequence of
        (Lq_i, dim) arrays or stacked (B, Lq, dim); pids: (B, C) (−1 pad).
        Returns scores (B, C) aligned with pids — one residual gather
        (deduplicated across the batch) and one scoring dispatch."""
        q, q_valid = pad_query_batch(q_embs)
        pids = np.asarray(pids)
        B, q, q_valid, pids_p = _pad_batch_rows(q, q_valid, pids)
        codes, packed, valid = self.gather_tokens_batch(pids_p)
        return self.score_gathered_batch(q, q_valid, codes, packed, valid,
                                         pids_p, B)

    # -- deduplicated host gather (shared mmap pages per batch) ------------
    def _dedup_gather(self, pids_b: np.ndarray, *, codes_only: bool):
        """pids_b (B, C) (−1 pad) → per-query (codes (B, C, Ld),
        packed (B, C, Ld, pd) | None, valid (B, C, Ld)) through ONE
        PagedStore gather over the deduplicated pid set, so co-batched
        queries fault each index page at most once."""
        real = pids_b[pids_b >= 0]
        uniq = np.unique(real) if real.size else np.zeros(1, np.int64)
        if codes_only:
            codes_u, valid_u = self.index.gather_doc_codes(uniq)
            packed_u = None
        else:
            codes_u, packed_u, valid_u = self.index.gather_doc_tokens(uniq)
        pos = np.searchsorted(uniq, np.clip(pids_b, 0, None))
        pos = np.minimum(pos, len(uniq) - 1)
        mask = (pids_b >= 0)[..., None]
        codes = codes_u[pos]
        valid = valid_u[pos] & mask
        packed = None if packed_u is None else packed_u[pos]
        return codes, packed, valid

    # -- device-resident gather --------------------------------------------
    def _gather_device(self, pids):
        idx = self.index
        safe = jnp.clip(pids, 0, idx.n_docs - 1)
        starts = self.dev_offsets[safe]
        tok = starts[:, None] + jnp.arange(idx.doc_maxlen)[None, :]
        tok = jnp.minimum(tok, idx.store.n_tokens - 1)
        codes = self.dev_codes[tok]
        packed = self.dev_residuals[tok]
        valid = (jnp.arange(idx.doc_maxlen)[None, :] <
                 self.dev_doclens[safe][:, None]) & (pids >= 0)[:, None]
        return codes, packed, valid

    def _gather_device_batch(self, pids):
        """pids (B, C) → device arrays reshaped to (B, C, Ld[, pd])."""
        B, C = pids.shape
        codes, packed, valid = self._gather_device(pids.reshape(-1))
        ld = self.index.doc_maxlen
        return (codes.reshape(B, C, ld), packed.reshape(B, C, ld, -1),
                valid.reshape(B, C, ld))
