"""PLAID multi-stage search over the compressed ColBERTv2 index.

Stages (Santhanam et al., CIKM'22):
  1. centroid scoring:   S_c = Q · C^T, top-``nprobe`` centroids/q-token
  2. candidate generation from the IVF
  3. approximate scoring by centroid interaction (codes only — cheap,
     *no residual access*)
  4. residual decompression + exact MaxSim for the surviving ``ndocs``

The class orchestrates jitted device stages with host gathers through
the PagedStore (mmap tier), mirroring the paper's Python↔C++ split.
``device_resident=True`` instead keeps the whole pool in device memory
and exposes a single jitted ``serve_step`` — that path is what the
multi-pod dry-run lowers, with the pool sharded over the 'model' axis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.builder import ColBERTIndex
from repro.index.residual import unpack_codes
from repro.models.colbert import maxsim


@dataclasses.dataclass(frozen=True)
class PlaidParams:
    nprobe: int = 4
    candidate_cap: int = 4096    # max candidate pids after stage 2
    ndocs: int = 256             # survivors entering exact scoring
    k: int = 100                 # final results


# --------------------------------------------------------------------------
# jitted stage kernels (shapes static per index)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("nprobe",))
def stage1_centroid_probe(q_emb, centroids, nprobe: int):
    """q_emb (Lq, d), centroids (K, d) → (scores_c (Lq, K), top cids)."""
    s = jnp.einsum("qd,kd->qk", q_emb, centroids,
                   preferred_element_type=jnp.float32)
    _, cids = jax.lax.top_k(s, nprobe)
    return s, cids.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cap",))
def stage2_candidates(ivf_padded, cids, cap: int):
    """ivf_padded (K, P) int32 (−1 fill); cids (Lq, nprobe) →
    unique candidate pids (cap,) (−1 fill)."""
    cand = ivf_padded[cids.reshape(-1)].reshape(-1)      # (Lq*nprobe*P,)
    # unique with static size; -1 fill sorts first so drop via where
    uniq = jnp.unique(cand, size=cap + 1, fill_value=-1)
    uniq = jnp.where(uniq >= 0, uniq, -1)
    # compact: move -1s to the back (sort by (is_pad, value))
    order = jnp.argsort(jnp.where(uniq >= 0, 0, 1), stable=True)
    return uniq[order][:cap]


@jax.jit
def stage3_approx_score(scores_c, cand_codes, cand_valid, q_valid=None):
    """Centroid-interaction approximation.

    scores_c: (Lq, K); cand_codes: (C, Ld) int32 centroid ids;
    cand_valid: (C, Ld) → approx scores (C,)."""
    s = scores_c[:, cand_codes]                  # (Lq, C, Ld)
    s = jnp.where(cand_valid[None], s, -1e30)
    per_q = jnp.max(s, axis=-1)                  # (Lq, C)
    per_q = jnp.where(per_q <= -1e29, 0.0, per_q)
    if q_valid is not None:
        per_q = per_q * q_valid[:, None]
    return jnp.sum(per_q, axis=0)                # (C,)


@functools.partial(jax.jit, static_argnames=("nbits",))
def stage4_exact_score(q_emb, packed, cids, valid, centroids,
                       bucket_weights, nbits: int):
    """Decompress-and-MaxSim: packed (C, Ld, pd) uint8, cids (C, Ld)."""
    codes = unpack_codes(packed, nbits)
    emb = centroids[cids] + bucket_weights[codes.astype(jnp.int32)]
    emb = emb * valid[..., None]
    return maxsim(q_emb, emb, valid)


# --------------------------------------------------------------------------
# Orchestrator
# --------------------------------------------------------------------------

class PLAIDSearcher:
    def __init__(self, index: ColBERTIndex, params: PlaidParams = PlaidParams(),
                 device_resident: bool = False, ivf_pad: Optional[int] = None):
        self.index = index
        self.params = params
        self.centroids = jnp.asarray(index.centroids)
        self.bucket_weights = jnp.asarray(index.bucket_weights)
        self.ivf_padded = jnp.asarray(index.ivf.as_padded(ivf_pad))
        self.device_resident = device_resident
        if device_resident:
            # whole pool in device memory (the in-memory ColBERTv2 baseline
            # or the TPU serve path with the pool sharded over 'model')
            self.dev_codes = jnp.asarray(np.asarray(index.store.codes))
            self.dev_residuals = jnp.asarray(np.asarray(index.store.residuals))
            self.dev_offsets = jnp.asarray(index.doc_offsets)
            self.dev_doclens = jnp.asarray(index.doclens)

    # -- full PLAID (stages 1-4) ------------------------------------------
    def search(self, q_emb: np.ndarray, k: Optional[int] = None):
        """q_emb: (Lq, dim). Returns (pids (k,), scores (k,)) desc."""
        p = self.params
        k = k or p.k
        q = jnp.asarray(q_emb)
        scores_c, cids = stage1_centroid_probe(q, self.centroids, p.nprobe)
        cand = stage2_candidates(self.ivf_padded, cids, p.candidate_cap)

        cand_np = np.asarray(cand)
        n_real = int((cand_np >= 0).sum())
        if self.device_resident:
            codes, packed, valid = self._gather_device(cand)
        else:
            codes_np, packed_np, valid_np = \
                self.index.gather_doc_tokens(cand_np)
            codes, valid = jnp.asarray(codes_np), jnp.asarray(valid_np)

        approx = stage3_approx_score(scores_c, codes, valid)
        approx = jnp.where(cand >= 0, approx, -jnp.inf)
        ndocs = min(p.ndocs, p.candidate_cap)
        _, keep = jax.lax.top_k(approx, ndocs)
        final_pids = cand[keep]

        if self.device_resident:
            f_codes, f_packed, f_valid = self._gather_device(final_pids)
        else:
            # Stage 4 is the only residual access — this is where the
            # mmap pages get touched.
            c_np, r_np, v_np = self.index.gather_doc_tokens(
                np.asarray(final_pids))
            f_codes, f_packed, f_valid = (jnp.asarray(c_np),
                                          jnp.asarray(r_np),
                                          jnp.asarray(v_np))

        exact = stage4_exact_score(q, f_packed, f_codes, f_valid,
                                   self.centroids, self.bucket_weights,
                                   self.index.nbits)
        exact = jnp.where(final_pids >= 0, exact, -jnp.inf)
        k_eff = min(k, ndocs)
        top_s, idx = jax.lax.top_k(exact, k_eff)
        out_pids = np.full(k, -1, np.int64)
        out_scores = np.full(k, -np.inf, np.float32)
        out_pids[:k_eff] = np.asarray(final_pids[idx])
        out_scores[:k_eff] = np.asarray(top_s)
        return out_pids, out_scores, {"candidates": n_real}

    # -- rerank-only (stage 4 on external candidates) ----------------------
    def rerank(self, q_emb: np.ndarray, pids: np.ndarray):
        """Exact MaxSim for given candidates (the paper's Rerank path).
        pids: (C,) (−1 pad). Returns scores (C,) aligned with pids."""
        q = jnp.asarray(q_emb)
        if self.device_resident:
            codes, packed, valid = self._gather_device(jnp.asarray(pids))
        else:
            c_np, r_np, v_np = self.index.gather_doc_tokens(np.asarray(pids))
            codes, packed, valid = (jnp.asarray(c_np), jnp.asarray(r_np),
                                    jnp.asarray(v_np))
        scores = stage4_exact_score(q, packed, codes, valid, self.centroids,
                                    self.bucket_weights, self.index.nbits)
        return np.asarray(jnp.where(jnp.asarray(pids) >= 0, scores, -jnp.inf))

    # -- device-resident gather --------------------------------------------
    def _gather_device(self, pids):
        idx = self.index
        safe = jnp.clip(pids, 0, idx.n_docs - 1)
        starts = self.dev_offsets[safe]
        tok = starts[:, None] + jnp.arange(idx.doc_maxlen)[None, :]
        tok = jnp.minimum(tok, idx.store.n_tokens - 1)
        codes = self.dev_codes[tok]
        packed = self.dev_residuals[tok]
        valid = (jnp.arange(idx.doc_maxlen)[None, :] <
                 self.dev_doclens[safe][:, None]) & (pids >= 0)[:, None]
        return codes, packed, valid
