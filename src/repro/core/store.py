"""PagedStore: the paper's memory-mapping contribution, as a framework
primitive.

Two tiers are modelled:

1. **Host tier (faithful reproduction)** — the compressed index tensors
   (packed residual codes + centroid ids) live in files and are opened
   either fully-in-RAM (``mode="ram"``, np.fromfile — the ColBERTv2
   baseline) or memory-mapped (``mode="mmap"``, np.memmap — the paper's
   system). With mmap, the OS pages data in on access; we additionally
   track which 4 KiB pages each gather touches so tests can assert the
   multi-stage pipeline's access-minimisation claim directly.

2. **Device tier (TPU adaptation)** — ``DeviceBlockCache`` pins
   fixed-size token-blocks of the pool in device memory (HBM stand-in)
   with LRU eviction. Candidate gathers fetch only missing blocks. This
   is the HBM↔host analogue of page-cache behaviour and is shared by
   the recsys ``TieredEmbedding`` and the paged KV cache.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import threading
from collections import OrderedDict
from typing import Optional

import jax
import numpy as np

PAGE_BYTES = 4096


def rss_bytes() -> int:
    """Resident set size of this process (Linux)."""
    with open("/proc/self/status") as f:
        for line in f:
            if line.startswith("VmRSS:"):
                return int(line.split()[1]) * 1024
    return -1


@dataclasses.dataclass
class AccessStats:
    """Mmap access accounting. Mutation is thread-safe: the pipelined
    serving path updates it from dedicated gather-stage workers while
    benchmarks/health endpoints read it concurrently — all mutation
    goes through :meth:`account` under a lock, and readers that need a
    coherent view take :meth:`snapshot`. (Bare field reads remain fine
    for single-threaded tests.)"""

    gathers: int = 0
    tokens_read: int = 0
    pages_touched: int = 0            # residual pages, cumulative
    unique_pages: Optional[set] = None  # residual pages, deduplicated
    residual_gathers: int = 0         # gathers that faulted residual rows
    residual_tokens_read: int = 0     # rows read from the residual file

    def __post_init__(self):
        self._lock = threading.Lock()

    def reset(self):
        with self._lock:
            self.gathers = 0
            self.tokens_read = 0
            self.pages_touched = 0
            self.unique_pages = set()
            self.residual_gathers = 0
            self.residual_tokens_read = 0

    def account(self, token_ids: np.ndarray, packed_dim: int,
                residuals: bool = True):
        """Record one gather of ``token_ids`` rows (atomically)."""
        n = int(token_ids.size)
        if residuals:
            # which 4 KiB pages of residuals.bin do these rows touch?
            byte_lo = token_ids.astype(np.int64) * packed_dim
            pages = np.unique(byte_lo // PAGE_BYTES)
        with self._lock:
            self.gathers += 1
            self.tokens_read += n
            if not residuals:
                return
            self.residual_gathers += 1
            self.residual_tokens_read += n
            self.pages_touched += len(pages)
            if self.unique_pages is not None:
                self.unique_pages.update(pages.tolist())

    def snapshot(self) -> dict:
        """Atomic, plain-dict copy for cross-thread readers (per-stage
        instrumentation deltas, tests, benchmarks)."""
        with self._lock:
            return {"gathers": self.gathers,
                    "tokens_read": self.tokens_read,
                    "pages_touched": self.pages_touched,
                    "unique_pages": len(self.unique_pages or ()),
                    "residual_gathers": self.residual_gathers,
                    "residual_tokens_read": self.residual_tokens_read}


class PagedStore:
    """Column store of per-token index payloads with ram/mmap modes."""

    def __init__(self, path, mode: str = "mmap"):
        self.path = pathlib.Path(path)
        self.mode = mode
        meta = json.loads((self.path / "meta.json").read_text())
        self.n_tokens = meta["n_tokens"]
        self.packed_dim = meta["packed_dim"]
        self.nbits = meta["nbits"]
        self.dim = meta["dim"]

        rbytes = self.n_tokens * self.packed_dim
        if mode == "mmap":
            self.residuals = np.memmap(self.path / "residuals.bin", np.uint8,
                                       "r", shape=(self.n_tokens, self.packed_dim))
            self.codes = np.memmap(self.path / "codes.bin", np.int32, "r",
                                   shape=(self.n_tokens,))
        elif mode == "ram":
            self.residuals = np.fromfile(self.path / "residuals.bin",
                                         np.uint8).reshape(self.n_tokens,
                                                           self.packed_dim)
            self.codes = np.fromfile(self.path / "codes.bin", np.int32)
        else:
            raise ValueError(mode)
        assert self.residuals.size == rbytes
        self.stats = AccessStats()
        self.stats.reset()

    # -- access ---------------------------------------------------------
    def gather_tokens(self, token_ids: np.ndarray):
        """token_ids: (N,) int64 → (codes (N,), residuals (N, packed))."""
        token_ids = np.asarray(token_ids)
        res = self.residuals[token_ids]
        cds = self.codes[token_ids]
        self._account(token_ids)
        return cds, res

    def gather_ranges(self, starts: np.ndarray, length: int):
        """Uniform-stride gather: rows [s, s+length) per start (clamped)."""
        flat = self._range_ids(starts, length)
        res = self.residuals[flat].reshape(len(starts), length, self.packed_dim)
        cds = self.codes[flat].reshape(len(starts), length)
        self._account(flat)
        return cds, res

    def gather_codes_ranges(self, starts: np.ndarray, length: int):
        """Codes-only uniform-stride gather for the approximate stage:
        reads centroid ids and *never touches a residual page* — the
        access pattern the paper's stage 3 relies on in mmap mode."""
        flat = self._range_ids(starts, length)
        cds = self.codes[flat].reshape(len(starts), length)
        self._account(flat, residuals=False)
        return cds

    def _range_ids(self, starts: np.ndarray, length: int):
        idx = starts[:, None] + np.arange(length)[None, :]
        idx = np.minimum(idx, self.n_tokens - 1)
        return idx.reshape(-1)

    def _account(self, token_ids, residuals: bool = True):
        self.stats.account(token_ids, self.packed_dim, residuals=residuals)

    # -- info -------------------------------------------------------------
    def total_bytes(self) -> int:
        return self.n_tokens * (self.packed_dim + 4)

    def resident_fraction_estimate(self) -> float:
        """Fraction of the pool's pages ever touched (mmap working set)."""
        total_pages = max(1, self.total_bytes() // PAGE_BYTES)
        return len(self.stats.unique_pages or ()) / total_pages

    # -- construction ------------------------------------------------------
    @staticmethod
    def write(path, codes: np.ndarray, residuals: np.ndarray, *, dim: int,
              nbits: int):
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        n_tokens, packed_dim = residuals.shape
        residuals.astype(np.uint8).tofile(path / "residuals.bin")
        codes.astype(np.int32).tofile(path / "codes.bin")
        (path / "meta.json").write_text(json.dumps({
            "n_tokens": int(n_tokens), "packed_dim": int(packed_dim),
            "dim": dim, "nbits": nbits}))


class DeviceBlockCache:
    """LRU block cache: host pool → device arrays (the HBM tier).

    The pool is split into blocks of ``block_tokens`` rows. ``lookup``
    returns device arrays for the requested blocks, fetching misses via
    ``jax.device_put`` and evicting least-recently-used blocks beyond
    ``capacity_blocks``. Miss/hit counters feed the latency model and
    benchmarks.
    """

    def __init__(self, store: PagedStore, block_tokens: int = 4096,
                 capacity_blocks: int = 64):
        self.store = store
        self.block_tokens = block_tokens
        self.capacity = capacity_blocks
        self._cache: OrderedDict[int, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def n_blocks(self) -> int:
        return -(-self.store.n_tokens // self.block_tokens)

    def _fetch(self, block_id: int):
        lo = block_id * self.block_tokens
        hi = min(lo + self.block_tokens, self.store.n_tokens)
        idx = np.arange(lo, hi)
        cds, res = self.store.gather_tokens(idx)
        pad = self.block_tokens - (hi - lo)
        if pad:
            cds = np.pad(cds, (0, pad))
            res = np.pad(res, ((0, pad), (0, 0)))
        return (jax.device_put(cds), jax.device_put(res))

    def lookup(self, block_ids):
        out = {}
        for b in dict.fromkeys(int(b) for b in block_ids):
            if b in self._cache:
                self._cache.move_to_end(b)
                self.hits += 1
            else:
                self.misses += 1
                self._cache[b] = self._fetch(b)
                while len(self._cache) > self.capacity:
                    self._cache.popitem(last=False)
            out[b] = self._cache[b]
        return out

    def gather_rows(self, token_ids: np.ndarray):
        """Gather rows through the block cache (device-side assembly)."""
        import jax.numpy as jnp
        token_ids = np.asarray(token_ids)
        blocks = token_ids // self.block_tokens
        cache = self.lookup(np.unique(blocks))
        cds = np.zeros(token_ids.shape, np.int32)
        res = np.zeros((*token_ids.shape, self.store.packed_dim), np.uint8)
        flat_ids = token_ids.reshape(-1)
        flat_blocks = flat_ids // self.block_tokens
        # assemble per-block (host copy of device block slices)
        cds_f = cds.reshape(-1)
        res_f = res.reshape(-1, self.store.packed_dim)
        for b in np.unique(flat_blocks):
            sel = flat_blocks == b
            off = flat_ids[sel] - b * self.block_tokens
            bc, br = cache[int(b)]
            cds_f[sel] = np.asarray(jnp.take(bc, off, axis=0))
            res_f[sel] = np.asarray(jnp.take(br, off, axis=0))
        return cds_f.reshape(token_ids.shape), \
            res_f.reshape(*token_ids.shape, self.store.packed_dim)
