"""Scatter-gather serving over a sharded SPLADE/PLAID/mmap index.

The corpus is partitioned into ``n_shards`` contiguous document ranges
(``repro.index.sharding``); each shard owns its own SPLADE postings
slice, PLAID IVF slice, and mmap ``PagedStore`` segment, wrapped in an
ordinary per-shard :class:`MultiStageRetriever`. This module's
:class:`ShardedRetriever` presents the same retriever interface over
the whole group by compiling *sharded* stage plans:

* per-shard host work runs as pooled ``fanout`` stages
  (``Stage.fanout``) — the stage function executes once per shard,
  concurrently on the group's thread pool. For ``host_gather`` stages
  that is the point of the topology: independent mmap segments fault
  independent page streams, so gather bandwidth scales with the shard
  count instead of serialising on one file's page-in queue. Device
  work either fans out with async dispatches (PLAID stages) or runs as
  a dispatch-all-then-sync-all group stage (SPLADE stage 1), so shard
  devices execute concurrently without pooling the GIL-bound Python
  dispatch itself.
* shard-local candidates are remapped to **global** doc ids
  (``local + shard_offset``) the moment they leave a shard, and a
  ``merge_topk`` fuse stage combines per-shard top-k lists into the
  global ranking.

Parity contract (tested in ``tests/test_sharding.py``): shard-local
scores are bit-identical to the single index's scores for the same
document (shared quantisation / geometry), and every top-k selection —
per shard and at the merges — orders by (score desc, pid asc). Top-k
selection distributes over a partition under that total order, so
shards=k returns the same results as shards=1 for all four methods.
Two documented deviations: a per-shard ``candidate_cap`` truncates
later than a global one (strictly more candidates survive — never
fewer), and exact-score ties at the final merge resolve by global pid
rather than approx-rank.
"""

from __future__ import annotations

import os
import pathlib
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.core import hybrid as hybrid_mod
from repro.core.multistage import MultiStageRetriever
from repro.core.plaid import (
    _pad_batch_rows,
    pad_query_batch_host,
    stage3_approx_score_batch,
)
from repro.serving.pipeline import (
    DEVICE,
    HOST,
    PipelineStats,
    Stage,
    StagePlan,
)


def merge_topk(pids: np.ndarray, scores: np.ndarray, k: int,
               pad_score: float = -np.inf):
    """Merge concatenated per-shard top-k lists into the global top-k.

    ``pids``/``scores``: (B, S·K) with -1 marking padding. Selection
    orders by (score desc, global pid asc) — the same total order every
    per-shard list was built with, so the merged prefix equals the
    single-index top-k even through score ties. Returns
    ((B, k) pids -1-padded, (B, k) scores ``pad_score``-padded)."""
    key = np.where(pids >= 0, scores, -np.inf).astype(np.float32)
    # lexsort: last key is primary → score desc, then pid asc; padding
    # (-inf) sorts to the back regardless of its pid
    order = np.lexsort((np.where(pids >= 0, pids, np.iinfo(np.int64).max),
                        -key.astype(np.float64)), axis=1)[:, :k]
    top = np.take_along_axis(key, order, axis=1)
    out_pids = np.where(top > -np.inf,
                        np.take_along_axis(pids, order, axis=1), -1)
    out_scores = np.where(top > -np.inf, top, pad_score).astype(np.float32)
    w = order.shape[1]
    if w < k:
        out_pids = np.pad(out_pids, ((0, 0), (0, k - w)),
                          constant_values=-1)
        out_scores = np.pad(out_scores.astype(np.float32),
                            ((0, 0), (0, k - w)),
                            constant_values=np.float32(pad_score))
    return out_pids.astype(np.int64), out_scores


def compact_owned(gpids: np.ndarray, lo: int, hi: int, min_w: int = 8):
    """Compact one shard's slice of a global candidate matrix.

    ``gpids``: (B, C) global pids (−1 pad). Returns (cols, local), both
    (B, W) with W = pow2 bucket of the densest row's owned count (≤ C):
    ``local`` holds shard-local pids for the candidates this shard owns
    (−1 pad) and ``cols`` the *global column* each came from, so scores
    computed on the narrow slice scatter back into the global matrix
    (:func:`scatter_scores`). Gather/score work per shard is then
    O(owned) ≈ C/S instead of O(C) — without this, every shard pays the
    full candidate width and scatter-gather costs S× the single index.
    """
    owned = (gpids >= lo) & (gpids < hi)
    w = int(owned.sum(axis=1).max()) if gpids.size else 0
    W = min(_next_pow2(max(w, min_w)), max(gpids.shape[1], 1))
    # stable sort on ~owned floats owned columns to the front, keeping
    # their global order
    order = np.argsort(~owned, axis=1, kind="stable")[:, :W]
    ow = np.take_along_axis(owned, order, axis=1)
    cols = np.where(ow, order, -1)
    local = np.where(ow, np.take_along_axis(gpids, order, axis=1) - lo, -1)
    return cols, local


def scatter_scores(out: np.ndarray, cols: np.ndarray,
                   scores: np.ndarray):
    """Scatter one shard's (B, W) scores back into the (B, C) global
    matrix at the columns ``compact_owned`` recorded (−1 skipped)."""
    m = cols >= 0
    rows = np.broadcast_to(np.arange(out.shape[0])[:, None],
                           cols.shape)[m]
    out[rows, cols[m]] = scores[m]


class CombinedAccessStats:
    """Duck-typed ``AccessStats`` view over a shard group: ``snapshot``
    sums the per-segment counters so sharded plans report pages/tokens
    exactly like a single store would."""

    def __init__(self, parts: Sequence):
        self.parts = list(parts)

    def snapshot(self) -> dict:
        out: dict = {}
        for part in self.parts:
            for key, val in part.snapshot().items():
                out[key] = out.get(key, 0) + val
        return out

    def reset(self):
        for part in self.parts:
            part.reset()


class ShardedRetriever(MultiStageRetriever):
    """Scatter-gather retriever over per-shard ``MultiStageRetriever``s.

    ``shards``: one retriever per contiguous doc range;
    ``shard_offsets``: (n_shards+1,) global doc-id boundaries (shard i
    owns global pids [offsets[i], offsets[i+1])). All shards must share
    params (the plan closes over one copy).

    With ``n_shards == 1`` every entry point delegates to the single
    shard, so the one-shard group is *bitwise* the unsharded path.
    """

    def __init__(self, shards: Sequence[MultiStageRetriever],
                 shard_offsets, pool: Optional[ThreadPoolExecutor] = None):
        if not shards:
            raise ValueError("empty shard group")
        self.shards = list(shards)
        self.offsets = np.asarray(shard_offsets, np.int64)
        if len(self.offsets) != len(self.shards) + 1:
            raise ValueError(
                f"{len(self.shards)} shards need {len(self.shards) + 1} "
                f"boundaries, got {len(self.offsets)}")
        for sh in self.shards[1:]:
            if sh.params != self.shards[0].params:
                raise ValueError("shards must share MultiStageParams")
        self.params = self.shards[0].params
        self.n_shards = len(self.shards)
        self.n_docs = int(self.offsets[-1])
        self._lock = threading.Lock()
        self._plans: dict = {}
        self.pipeline_stats = PipelineStats()
        # gather concurrency capped at the core count: more threads than
        # cores just thrash the GIL between the gathers' Python segments
        # (measured 2x slower at 4 shards on 2 cores) without adding
        # page-fault streams the machine could actually service
        workers = min(self.n_shards, max(1, os.cpu_count() or 1))
        self._pool = pool or ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="shard")
        self.set_splade_backend(self.params.splade_backend)

    # ------------------------------------------------------------------
    # group-wide knobs
    # ------------------------------------------------------------------
    def set_splade_backend(self, backend: str):
        """Switch every shard's stage-1 scorer (plans are keyed on the
        backend, so the next ``compile_plan`` recompiles)."""
        for sh in self.shards:
            sh.set_splade_backend(backend)
        self.splade_backend = backend

    def splade_device_cache(self):
        """Materialise every shard's padded-postings device cache (each
        on its shard's device when one was assigned)."""
        return [sh.splade_device_cache() for sh in self.shards]

    def run_splade_batch(self, term_ids, term_weights, k=None,
                         backend=None, _record=True):
        """Group-wide stage 1: per-shard scoring + global merge. Kept
        for API completeness (benchmarks poke stage 1 directly); the
        serving paths go through the compiled plans."""
        k = self.params.first_k if k is None else k
        outs = list(self._pool.map(
            lambda i: self.shards[i].run_splade_batch(
                term_ids, term_weights, k, backend=backend,
                _record=_record),
            range(self.n_shards)))
        pids = np.concatenate(
            [np.where(p >= 0, p + self.offsets[i], -1)
             for i, (p, _) in enumerate(outs)], axis=1)
        scores = np.concatenate([s for _, s in outs], axis=1)
        return merge_topk(pids, scores, k, pad_score=0.0)

    # ------------------------------------------------------------------
    # search entry points (n_shards == 1 delegates: bitwise-unsharded)
    # ------------------------------------------------------------------
    def search(self, method, q_emb=None, term_ids=None, term_weights=None,
               alpha=None, k=None):
        if self.n_shards == 1:
            return self.shards[0].search(
                method, q_emb=q_emb, term_ids=term_ids,
                term_weights=term_weights, alpha=alpha, k=k)
        wrap = (lambda x: None if x is None else [x])
        pids, scores = self.search_batch(
            method, q_embs=wrap(q_emb), term_ids=wrap(term_ids),
            term_weights=wrap(term_weights), alpha=alpha, k=k)
        return pids[0], scores[0]

    def search_batch(self, method, q_embs=None, term_ids=None,
                     term_weights=None, alpha=None, k=None):
        if self.n_shards == 1:
            return self.shards[0].search_batch(
                method, q_embs=q_embs, term_ids=term_ids,
                term_weights=term_weights, alpha=alpha, k=k)
        return super().search_batch(method, q_embs=q_embs,
                                    term_ids=term_ids,
                                    term_weights=term_weights,
                                    alpha=alpha, k=k)

    def compile_plan(self, method: str) -> StagePlan:
        if self.n_shards == 1:
            return self.shards[0].compile_plan(method)
        return super().compile_plan(method)

    # ------------------------------------------------------------------
    # sharded stage plans
    # ------------------------------------------------------------------
    def _build_plan(self, method: str) -> StagePlan:
        """Compile the scatter-gather stage graph for one method.

        Stage discipline matches the unsharded plans (host stages touch
        only numpy; device dispatches and syncs live in device-kind
        stages), with two additions: per-shard stages carry
        ``fanout=n_shards`` and read/write the batch's shard axis, and
        ``merge_topk`` fuses run on the host over already-synced per-
        shard arrays."""
        p = self.params
        S = self.n_shards
        offs = self.offsets
        shards = self.shards
        dr = shards[0].searcher.device_resident
        gather_kind = DEVICE if dr else HOST
        access = None if dr else CombinedAccessStats(
            [sh.searcher.index.store.stats for sh in shards])
        ndocs = min(shards[0].searcher.params.ndocs,
                    shards[0].searcher.params.candidate_cap)

        if method == "colbert":
            from repro.core.plaid import (
                pad_query_batch,
                stage1_centroid_probe_batch,
                stage2_candidates_batch,
            )

            def probe(cb):
                # ONE centroid probe for the whole group: the centroid
                # set is replicated (geometry, not corpus), so a
                # per-shard probe would duplicate the einsum S times
                # for identical results
                sr = shards[0].searcher
                q, q_valid = pad_query_batch(cb.q_embs)
                B, q, q_valid = _pad_batch_rows(q, q_valid)
                scores_c, cids = stage1_centroid_probe_batch(
                    q, q_valid, sr.centroids, sr.params.nprobe)
                return cb.with_state(B=B, q=q, q_valid=q_valid,
                                     scores_c=scores_c, cids=cids)

            def candidates(cb, i):
                # per-shard candidate generation from the shard's IVF
                # slice; narrowed to the densest row's pow2 bucket (the
                # -1 fill is already compacted to the back) so the
                # codes gather and approx dispatch run at the shard's
                # ~cap/S occupancy, not the full global cap
                sr = shards[i].searcher
                cand = stage2_candidates_batch(
                    sr.ivf_padded, cb.state["cids"],
                    sr.params.candidate_cap)
                cand_np = np.asarray(cand)
                n_real = (cand_np >= 0).sum(axis=1)
                W = min(_next_pow2(max(int(n_real.max()), 8)),
                        cand_np.shape[1])
                return {"cand": cand[:, :W], "cand_np": cand_np[:, :W],
                        "n_real": n_real}

            def gather_codes(cb, i):
                s = dict(cb.shard_states[i])
                if dr:
                    codes, valid = shards[i].searcher.gather_codes_batch(
                        s["cand"])
                else:
                    codes, _, valid = shards[i].searcher._dedup_gather(
                        s["cand_np"], codes_only=True)
                s.update(codes=codes, cvalid=valid)
                return s

            def approx(cb, i):
                # raw approximate scores, NOT a per-shard top-ndocs:
                # survivor selection must be global or a shard-local
                # ndocs cut would diverge from the single-index path
                s = dict(cb.shard_states[i])
                a = stage3_approx_score_batch(
                    cb.state["scores_c"], jnp.asarray(s["codes"]),
                    jnp.asarray(s["cvalid"]), cb.state["q_valid"])
                a = jnp.where(s["cand"] >= 0, a, -jnp.inf)
                s["approx_np"] = np.asarray(a)
                return s

            def merge_approx(cb):
                gpids = np.concatenate(
                    [np.where(s["cand_np"] >= 0,
                              s["cand_np"] + offs[i], -1)
                     for i, s in enumerate(cb.shard_states)], axis=1)
                ascore = np.concatenate(
                    [s["approx_np"] for s in cb.shard_states], axis=1)
                final_g, _ = merge_topk(gpids, ascore, ndocs)
                n_real = sum(s["n_real"][:cb.state["B"]]
                             for s in cb.shard_states)
                return cb.with_state(final_g=final_g, n_real=n_real)

            def gather_residuals(cb, i):
                s = dict(cb.shard_states[i])
                cols, sel = compact_owned(cb.state["final_g"],
                                          offs[i], offs[i + 1])
                if dr:
                    f_codes, f_packed, f_valid = \
                        shards[i].searcher.gather_tokens_batch(sel)
                else:
                    f_codes, f_packed, f_valid = \
                        shards[i].searcher._dedup_gather(
                            sel, codes_only=False)
                s.update(cols=cols, sel=sel, f_codes=f_codes,
                         f_packed=f_packed, f_valid=f_valid)
                return s

            def exact(cb, i):
                s = dict(cb.shard_states[i])
                st = cb.state
                ex = shards[i].searcher.exact_score_gathered(
                    st["q"], st["q_valid"], jnp.asarray(s["f_codes"]),
                    jnp.asarray(s["f_packed"]), jnp.asarray(s["f_valid"]),
                    jnp.asarray(s["sel"]))
                s["exact_np"] = np.asarray(ex)   # (Bp, W_i) narrow slice
                return s

            def fuse(cb):
                st = cb.state
                B, g = st["B"], st["final_g"]
                # every global candidate is owned by exactly one shard:
                # scatter each shard's narrow score slice back into the
                # global exact-score matrix
                ex = np.full(g.shape, -np.inf, np.float32)
                for s in cb.shard_states:
                    scatter_scores(ex, s["cols"], s["exact_np"])
                out_pids, out_scores = merge_topk(g[:B], ex[:B], cb.k)
                aux = [{"candidates": int(x)} for x in st["n_real"]]
                return cb.evolve(pids=out_pids,
                                 scores=out_scores).with_state(aux=aux)

            stages = (
                Stage("plaid_probe", DEVICE, probe),
                Stage("plaid_probe:ivf", DEVICE, candidates, fanout=S),
                Stage("host_gather:codes", gather_kind, gather_codes,
                      fanout=S, pooled=not dr),
                Stage("device_score:approx", DEVICE, approx, fanout=S),
                Stage("merge_topk:approx", HOST, merge_approx),
                Stage("host_gather:residuals", gather_kind,
                      gather_residuals, fanout=S, pooled=not dr),
                Stage("device_score:exact", DEVICE, exact, fanout=S),
                Stage("merge_topk", HOST, fuse))
            return StagePlan(method=method, stages=stages,
                             access_stats=access, pool=self._pool)

        s1_kind = HOST if self.splade_backend == "host" else DEVICE
        backend = self.splade_backend

        def splade_stage(cb):
            """Group stage 1, writing the shard axis itself. On the
            device backends every shard's dispatch is issued *before*
            any sync (``dispatch_topk``/``finalize_topk``), so with
            per-shard device pinning the accelerators score their
            postings slices concurrently — a per-shard sync loop would
            serialise them behind the first shard's result."""
            tids, tw = list(cb.term_ids), list(cb.term_weights)
            if backend == "host":
                outs = [sh.run_splade_batch(tids, tw, p.first_k,
                                            _record=False)
                        for sh in shards]
            else:
                impl = shards[0]._splade_impl(backend)
                disps = [sh.splade_device_cache().dispatch_topk(
                    tids, tw, p.first_k, impl=impl) for sh in shards]
                outs = [sh.splade_device_cache().finalize_topk(d)
                        for sh, d in zip(shards, disps)]
            return cb.evolve(shard_states=tuple(
                {"pids": np.where(pd >= 0, pd + offs[i], -1),
                 "scores": sc}
                for i, (pd, sc) in enumerate(outs)))

        def _merged_stage1(cb):
            """(B, first_k) global candidates — identical content and
            order to the single index's ``run_splade_batch``."""
            pids = np.concatenate([s["pids"] for s in cb.shard_states],
                                  axis=1)
            scores = np.concatenate([s["scores"]
                                     for s in cb.shard_states], axis=1)
            return merge_topk(pids, scores, p.first_k, pad_score=0.0)

        if method == "splade":
            def fuse_splade(cb):
                pids_b, s_scores = _merged_stage1(cb)
                return cb.evolve(pids=pids_b[:, :cb.k],
                                 scores=s_scores[:, :cb.k])

            stages = (Stage("splade_stage1", s1_kind, splade_stage),
                      Stage("merge_topk", HOST, fuse_splade))
            return StagePlan(method=method, stages=stages,
                             access_stats=access, pool=self._pool)

        # rerank / hybrid: merged SPLADE candidates → shard-parallel
        # residual gather → per-shard MaxSim → global fuse (+ α)
        def merge_stage1(cb):
            pids_b, s_scores = _merged_stage1(cb)
            q, q_valid = pad_query_batch_host(cb.q_embs)
            B, q, q_valid, gp = _pad_batch_rows(q, q_valid, pids_b)
            return cb.with_state(pids_b=pids_b, s_scores=s_scores,
                                 q=q, q_valid=q_valid, B=B, gp=gp)

        def gather(cb, i):
            st = cb.state
            cols, sel = compact_owned(st["gp"], offs[i], offs[i + 1])
            if dr:
                codes, packed, valid = \
                    shards[i].searcher.gather_tokens_batch(sel)
            else:
                codes, packed, valid = shards[i].searcher._dedup_gather(
                    sel, codes_only=False)
            return {"cols": cols, "sel": sel, "g_codes": codes,
                    "g_packed": packed, "g_valid": valid}

        def score(cb, i):
            s = dict(cb.shard_states[i])
            st = cb.state
            s["c_dev"] = shards[i].searcher.score_gathered_lazy(
                jnp.asarray(st["q"]), jnp.asarray(st["q_valid"]),
                jnp.asarray(s["g_codes"]), jnp.asarray(s["g_packed"]),
                jnp.asarray(s["g_valid"]), s["sel"])[:st["B"]]
            return s

        def fuse_rerank(cb):
            st = cb.state
            pids_b = st["pids_b"]
            # sync each shard's narrow lazy score slice and scatter it
            # back into the global candidate columns
            c_scores = np.full(pids_b.shape, -np.inf, np.float32)
            for s in cb.shard_states:
                scatter_scores(c_scores, s["cols"][:pids_b.shape[0]],
                               np.asarray(s["c_dev"]))
            if method == "rerank":
                final = np.where(pids_b >= 0, c_scores, -np.inf)
            else:
                mask = pids_b >= 0
                final = np.asarray(hybrid_mod.hybrid_scores(
                    jnp.asarray(st["s_scores"]), jnp.asarray(c_scores),
                    jnp.asarray(mask), alpha=jnp.asarray(cb.alphas),
                    normalizer=p.normalizer))
            order = np.argsort(-final, axis=1, kind="stable")[:, :cb.k]
            sorted_final = np.take_along_axis(final, order, axis=1)
            out_pids = np.where(
                sorted_final > -np.inf,
                np.take_along_axis(pids_b, order, axis=1), -1)
            return cb.evolve(pids=out_pids, scores=sorted_final)

        stages = (Stage("splade_stage1", s1_kind, splade_stage),
                  Stage("merge_topk:stage1", HOST, merge_stage1),
                  Stage("host_gather:residuals", gather_kind, gather,
                        fanout=S, pooled=not dr),
                  Stage("device_score:maxsim", DEVICE, score, fanout=S,
                        opens_async=True),
                  Stage("fuse_topk", DEVICE, fuse_rerank,
                        closes_async=True))
        return StagePlan(method=method, stages=stages,
                         access_stats=access, pool=self._pool)


def build_sharded_retriever(shard_dirs, boundaries, *, mode: str = "mmap",
                            plaid_params=None, multistage_params=None,
                            devices: Optional[Sequence] = None
                            ) -> ShardedRetriever:
    """Load a shard group written by ``split_index_tree`` into a
    :class:`ShardedRetriever`. ``shard_dirs``: per-shard directories
    each holding ``colbert/`` + ``splade/``; ``devices`` optionally
    pins shard i's device-resident state (SPLADE device cache) to
    ``devices[i]`` — see ``launch.mesh.shard_device_map``."""
    from repro.core.plaid import PLAIDSearcher, PlaidParams
    from repro.index.builder import ColBERTIndex
    from repro.index.splade_index import SpladeIndex

    plaid_params = plaid_params or PlaidParams()
    shards = []
    for i, d in enumerate(shard_dirs):
        d = pathlib.Path(d)
        index = ColBERTIndex(d / "colbert", mode=mode)
        sidx = SpladeIndex.load(d / "splade", mmap=(mode == "mmap"))
        searcher = PLAIDSearcher(index, plaid_params)
        kw = {} if multistage_params is None \
            else {"params": multistage_params}
        retr = MultiStageRetriever(
            sidx, searcher,
            device=None if devices is None else devices[i], **kw)
        shards.append(retr)
    return ShardedRetriever(shards, boundaries)
