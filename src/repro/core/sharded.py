"""Scatter-gather serving over a sharded SPLADE/PLAID/mmap index.

The corpus is partitioned into ``n_shards`` contiguous document ranges
(``repro.index.sharding``); each shard owns its own SPLADE postings
slice, PLAID IVF slice, and mmap ``PagedStore`` segment, wrapped in an
ordinary per-shard :class:`MultiStageRetriever`. This module's
:class:`ShardedRetriever` presents the same retriever interface over
the whole group by compiling *sharded* stage plans:

* per-shard host work runs as pooled ``fanout`` stages
  (``Stage.fanout``) — the stage function executes once per shard,
  concurrently on the group's thread pool. For ``host_gather`` stages
  that is the point of the topology: independent mmap segments fault
  independent page streams, so gather bandwidth scales with the shard
  count instead of serialising on one file's page-in queue. Device
  work either fans out with async dispatches (PLAID stages) or runs as
  a dispatch-all-then-sync-all group stage (SPLADE stage 1), so shard
  devices execute concurrently without pooling the GIL-bound Python
  dispatch itself.
* shard-local candidates are remapped to **global** doc ids
  (``local + shard_offset``) the moment they leave a shard, and a
  ``merge_topk`` fuse stage combines per-shard top-k lists into the
  global ranking.

Two worker backends share this plan vocabulary (and the merge/fuse
stage bodies, so they cannot drift):

* :class:`ShardedRetriever` — **thread workers**: every shard lives in
  this process; per-shard host gathers fan out on a thread pool,
  device dispatches are async.
* :class:`ProcessShardGroup` — **process workers**: each shard is its
  own OS process (``repro.serving.worker``) owning its mmap segment,
  page-cache working set, SPLADE device cache, and GIL; per-shard
  stage work crosses a compact RPC (``repro.serving.rpc``) and comes
  back as synced numpy. Selected by ``--shard-workers=process`` on
  ``repro.launch.serve``.

Parity contract (tested in ``tests/test_sharding.py`` and
``tests/test_process_group.py``): shard-local scores are bit-identical
to the single index's scores for the same document (shared
quantisation / geometry), and every top-k selection — per shard and at
the merges — orders by (score desc, pid asc). Top-k selection
distributes over a partition under that total order, so shards=k
returns the same results as shards=1 for all four methods, under
either worker backend. Two documented deviations: a per-shard
``candidate_cap`` truncates later than a global one (strictly more
candidates survive — never fewer), and exact-score ties at the final
merge resolve by global pid rather than approx-rank.
"""

from __future__ import annotations

import os
import pathlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.core import hybrid as hybrid_mod
from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import (
    _pad_batch_rows,
    pad_query_batch_host,
    stage3_approx_score_batch,
)
from repro.serving.pipeline import (
    DEVICE,
    HOST,
    PipelineStats,
    Stage,
    StagePlan,
)


def merge_topk(pids: np.ndarray, scores: np.ndarray, k: int,
               pad_score: float = -np.inf):
    """Merge concatenated per-shard top-k lists into the global top-k.

    ``pids``/``scores``: (B, S·K) with -1 marking padding. Selection
    orders by (score desc, global pid asc) — the same total order every
    per-shard list was built with, so the merged prefix equals the
    single-index top-k even through score ties. Returns
    ((B, k) pids -1-padded, (B, k) scores ``pad_score``-padded)."""
    key = np.where(pids >= 0, scores, -np.inf).astype(np.float32)
    # lexsort: last key is primary → score desc, then pid asc; padding
    # (-inf) sorts to the back regardless of its pid
    order = np.lexsort((np.where(pids >= 0, pids, np.iinfo(np.int64).max),
                        -key.astype(np.float64)), axis=1)[:, :k]
    top = np.take_along_axis(key, order, axis=1)
    out_pids = np.where(top > -np.inf,
                        np.take_along_axis(pids, order, axis=1), -1)
    out_scores = np.where(top > -np.inf, top, pad_score).astype(np.float32)
    w = order.shape[1]
    if w < k:
        out_pids = np.pad(out_pids, ((0, 0), (0, k - w)),
                          constant_values=-1)
        out_scores = np.pad(out_scores.astype(np.float32),
                            ((0, 0), (0, k - w)),
                            constant_values=np.float32(pad_score))
    return out_pids.astype(np.int64), out_scores


def compact_owned(gpids: np.ndarray, lo: int, hi: int, min_w: int = 8):
    """Compact one shard's slice of a global candidate matrix.

    ``gpids``: (B, C) global pids (−1 pad). Returns (cols, local), both
    (B, W) with W = pow2 bucket of the densest row's owned count (≤ C):
    ``local`` holds shard-local pids for the candidates this shard owns
    (−1 pad) and ``cols`` the *global column* each came from, so scores
    computed on the narrow slice scatter back into the global matrix
    (:func:`scatter_scores`). Gather/score work per shard is then
    O(owned) ≈ C/S instead of O(C) — without this, every shard pays the
    full candidate width and scatter-gather costs S× the single index.
    """
    owned = (gpids >= lo) & (gpids < hi)
    w = int(owned.sum(axis=1).max()) if gpids.size else 0
    W = min(_next_pow2(max(w, min_w)), max(gpids.shape[1], 1))
    # stable sort on ~owned floats owned columns to the front, keeping
    # their global order
    order = np.argsort(~owned, axis=1, kind="stable")[:, :W]
    ow = np.take_along_axis(owned, order, axis=1)
    cols = np.where(ow, order, -1)
    local = np.where(ow, np.take_along_axis(gpids, order, axis=1) - lo, -1)
    return cols, local


def scatter_scores(out: np.ndarray, cols: np.ndarray,
                   scores: np.ndarray):
    """Scatter one shard's (B, W) scores back into the (B, C) global
    matrix at the columns ``compact_owned`` recorded (−1 skipped)."""
    m = cols >= 0
    rows = np.broadcast_to(np.arange(out.shape[0])[:, None],
                           cols.shape)[m]
    out[rows, cols[m]] = scores[m]


# ---------------------------------------------------------------------------
# shared merge/fuse stage bodies
#
# Both shard-group backends — in-process thread workers
# (:class:`ShardedRetriever`) and shared-nothing process workers
# (:class:`ProcessShardGroup`) — run these exact functions for every
# coordinator-side merge and fuse, so the two backends cannot drift:
# given byte-identical per-shard states, the merged ranking is
# byte-identical by construction.
#
# Degraded mode: under ``allow_degraded`` a shard whose every replica
# is down contributes a ``{"missing": True}`` state *in its slot* (the
# shard axis stays positional — downstream offsets indexing depends on
# it). The merges skip missing slots and record the missing shard ids
# in ``cb.state["missing_shards"]``, so a partial answer is explicit
# all the way to the server response. A batch with zero surviving
# shards still fails (there is nothing to merge).
# ---------------------------------------------------------------------------

def _live_shard_states(shard_states):
    """Split the shard axis into surviving states (with their shard
    index) and the missing shard ids; raises when nothing survived."""
    live = [(i, s) for i, s in enumerate(shard_states)
            if not s.get("missing")]
    missing = tuple(i for i, s in enumerate(shard_states)
                    if s.get("missing"))
    if not live:
        from repro.serving.transport import ShardUnavailable
        raise ShardUnavailable(
            "every shard of the batch is unavailable — no partial "
            "answer to degrade to")
    return live, missing


def _note_missing(cb, missing):
    """Record (union) missing shard ids on the batch state; a no-op on
    the healthy path so thread-backend state stays byte-identical."""
    if not missing:
        return cb
    prior = cb.state.get("missing_shards", ())
    return cb.with_state(
        missing_shards=tuple(sorted(set(prior) | set(missing))))


def _concat_shard_topk(shard_states):
    """Concatenate per-shard stage-1 results (already remapped to
    global pids) along the candidate axis, skipping missing shards."""
    live, missing = _live_shard_states(shard_states)
    pids = np.concatenate([s["pids"] for _, s in live], axis=1)
    scores = np.concatenate([s["scores"] for _, s in live], axis=1)
    return pids, scores, missing


def _append_splade_delta(cb, pids, scores, first_k: int, live):
    """Widen the concatenated per-shard stage-1 rows with the live
    delta segment's top-k (global pids ≥ ``live.base_n``). Tombstoned
    *base* docs never reach here — each shard excluded them pre-top-k —
    and tombstoned delta docs are excluded inside ``splade_delta_topk``,
    so the merge below sees only surviving documents."""
    if live is None or not live.n_delta:
        return pids, scores
    d_pids, d_scores = live.splade_delta_topk(
        list(cb.term_ids), list(cb.term_weights), first_k)
    return (np.concatenate([pids, d_pids], axis=1),
            np.concatenate([scores, d_scores], axis=1))


def fuse_splade_state(cb, first_k: int, live=None):
    """Terminal fuse for the splade-only method: merge the per-shard
    stage-1 lists and truncate to the request's k. The full
    ``first_k``-wide merged rows are stashed in state so the stage-1
    cache can store them (a splade answer warms the same entry a later
    rerank/hybrid request reuses)."""
    pids, scores, missing = _concat_shard_topk(cb.shard_states)
    pids, scores = _append_splade_delta(cb, pids, scores, first_k, live)
    pids_b, s_scores = merge_topk(pids, scores, first_k, pad_score=0.0)
    cb = cb.evolve(pids=pids_b[:, :cb.k], scores=s_scores[:, :cb.k])
    cb = cb.with_state(pids_b=pids_b, s_scores=s_scores)
    return _note_missing(cb, missing)


def stage1_state_from_rows(cb, pids_b, s_scores):
    """Rebuild :func:`merge_stage1_state`'s output from cached merged
    rows — the stage-1 cache-hit path. The padding ops are the same
    calls the cold merge makes, so downstream gathers see byte-identical
    inputs."""
    B, q, q_valid, gp = _pad_batch_rows(
        *pad_query_batch_host(cb.q_embs), pids_b)
    return cb.with_state(pids_b=pids_b, s_scores=s_scores,
                         q=q, q_valid=q_valid, B=B, gp=gp)


def merge_stage1_state(cb, first_k: int, live=None):
    """(B, first_k) global candidates — identical content and order to
    the single index's ``run_splade_batch`` — plus the padded query
    batch the downstream gather/score stages consume."""
    pids, scores, missing = _concat_shard_topk(cb.shard_states)
    pids, scores = _append_splade_delta(cb, pids, scores, first_k, live)
    pids_b, s_scores = merge_topk(pids, scores, first_k, pad_score=0.0)
    q, q_valid = pad_query_batch_host(cb.q_embs)
    B, q, q_valid, gp = _pad_batch_rows(q, q_valid, pids_b)
    return _note_missing(
        cb.with_state(pids_b=pids_b, s_scores=s_scores,
                      q=q, q_valid=q_valid, B=B, gp=gp), missing)


def fuse_scatter_rerank(cb, method: str, normalizer: str, live=None):
    """Terminal rerank/hybrid fuse: sync each shard's narrow score
    slice (``c_dev`` — lazy device value or already-synced numpy),
    scatter it back into the global candidate columns, α-fuse for
    hybrid, and take the stable (score desc, pid asc) top-k."""
    st = cb.state
    pids_b = st["pids_b"]
    c_scores = np.full(pids_b.shape, -np.inf, np.float32)
    missing = []
    for i, s in enumerate(cb.shard_states):
        if s.get("missing"):
            missing.append(i)
            continue
        scatter_scores(c_scores, s["cols"][:pids_b.shape[0]],
                       np.asarray(s["c_dev"]))
    if live is not None and live.n_delta:
        # delta candidates are owned by no shard (their pids lie past
        # every boundary) — score them at the coordinator with the same
        # decompress+MaxSim kernel and fill their columns
        dmask = pids_b >= live.base_n
        if dmask.any():
            d_pids = np.where(dmask, pids_b, -1)
            pad = st["q"].shape[0] - d_pids.shape[0]
            if pad:
                d_pids = np.pad(d_pids, ((0, pad), (0, 0)),
                                constant_values=-1)
            d_scores = live.exact_scores(st["q"], st["q_valid"], d_pids)
            c_scores = np.where(dmask, d_scores[:pids_b.shape[0]],
                                c_scores)
    if method == "rerank":
        final = np.where(pids_b >= 0, c_scores, -np.inf)
    else:
        # candidates owned by a missing shard never received an exact
        # score: keep them out of the hybrid normalization. On the
        # healthy path every valid candidate has a finite score, so
        # this mask equals the plain ``pids_b >= 0`` mask bit-for-bit.
        mask = (pids_b >= 0) & (c_scores > -np.inf)
        final = np.asarray(hybrid_mod.hybrid_scores(
            jnp.asarray(st["s_scores"]), jnp.asarray(c_scores),
            jnp.asarray(mask), alpha=jnp.asarray(cb.alphas),
            normalizer=normalizer))
        if missing:
            final = np.where(mask, final, -np.inf)
    order = np.argsort(-final, axis=1, kind="stable")[:, :cb.k]
    sorted_final = np.take_along_axis(final, order, axis=1)
    out_pids = np.where(
        sorted_final > -np.inf,
        np.take_along_axis(pids_b, order, axis=1), -1)
    return _note_missing(cb.evolve(pids=out_pids, scores=sorted_final),
                         missing)


def merge_approx_state(cb, offsets, ndocs: int, live=None):
    """Global PLAID survivor selection: remap per-shard candidates to
    global pids, merge raw approx scores, and apply the ndocs cut
    *globally* (a shard-local cut would diverge from the single-index
    path). With a live overlay, tombstoned base candidates drop out
    pre-merge (pid −1 / −inf, exactly how padded candidate slots
    already behave) and the delta segment contributes its own
    candidates, approx-scored at the coordinator from the same probed
    centroid scores the shards used."""
    alive, missing = _live_shard_states(cb.shard_states)
    gpids = np.concatenate(
        [np.where(s["cand_np"] >= 0, s["cand_np"] + offsets[i], -1)
         for i, s in alive], axis=1)
    ascore = np.concatenate([s["approx_np"] for _, s in alive], axis=1)
    if live is not None and live.dirty:
        tomb = live.tombstone_array()
        if tomb.size:
            drop = np.isin(gpids, tomb) & (gpids >= 0)
            ascore = np.where(drop, -np.inf, ascore).astype(np.float32)
            gpids = np.where(drop, -1, gpids)
        if live.n_delta:
            d_lists = live.delta_candidates(np.asarray(cb.state["cids"]))
            W = max(1, max((len(x) for x in d_lists), default=0))
            d_mat = np.full((gpids.shape[0], W), -1, np.int64)
            for b, arr in enumerate(d_lists):
                d_mat[b, :len(arr)] = arr
            d_approx = live.approx_scores(
                cb.state["scores_c"], cb.state["q_valid"], d_mat)
            gpids = np.concatenate([gpids, d_mat], axis=1)
            ascore = np.concatenate([ascore, d_approx], axis=1)
    final_g, _ = merge_topk(gpids, ascore, ndocs)
    n_real = sum(s["n_real"][:cb.state["B"]] for _, s in alive)
    return _note_missing(cb.with_state(final_g=final_g, n_real=n_real),
                         missing)


def fuse_colbert_state(cb, live=None):
    """Terminal PLAID fuse: every global candidate is owned by exactly
    one shard — scatter each shard's narrow exact-score slice back into
    the global matrix and merge. Delta candidates (owned by no shard)
    are exact-scored at the coordinator."""
    st = cb.state
    B, g = st["B"], st["final_g"]
    ex = np.full(g.shape, -np.inf, np.float32)
    missing = []
    for i, s in enumerate(cb.shard_states):
        if s.get("missing"):
            missing.append(i)
            continue
        scatter_scores(ex, s["cols"], s["exact_np"])
    if live is not None and live.n_delta:
        dmask = g >= live.base_n
        if dmask.any():
            d_pids = np.where(dmask, g, -1)
            d_scores = live.exact_scores(st["q"], st["q_valid"], d_pids)
            ex = np.where(dmask, d_scores, ex)
    out_pids, out_scores = merge_topk(g[:B], ex[:B], cb.k)
    aux = [{"candidates": int(x)} for x in st["n_real"]]
    return _note_missing(
        cb.evolve(pids=out_pids, scores=out_scores).with_state(aux=aux),
        missing)


class CombinedAccessStats:
    """Duck-typed ``AccessStats`` view over a shard group: ``snapshot``
    sums the per-segment counters so sharded plans report pages/tokens
    exactly like a single store would."""

    def __init__(self, parts: Sequence):
        self.parts = list(parts)

    def snapshot(self) -> dict:
        out: dict = {}
        for part in self.parts:
            for key, val in part.snapshot().items():
                out[key] = out.get(key, 0) + val
        return out

    def reset(self):
        for part in self.parts:
            part.reset()


class ShardedRetriever(MultiStageRetriever):
    """Scatter-gather retriever over per-shard ``MultiStageRetriever``s.

    ``shards``: one retriever per contiguous doc range;
    ``shard_offsets``: (n_shards+1,) global doc-id boundaries (shard i
    owns global pids [offsets[i], offsets[i+1])). All shards must share
    params (the plan closes over one copy).

    With ``n_shards == 1`` every entry point delegates to the single
    shard, so the one-shard group is *bitwise* the unsharded path.
    """

    def __init__(self, shards: Sequence[MultiStageRetriever],
                 shard_offsets, pool: Optional[ThreadPoolExecutor] = None):
        if not shards:
            raise ValueError("empty shard group")
        self.shards = list(shards)
        self.offsets = np.asarray(shard_offsets, np.int64)
        if len(self.offsets) != len(self.shards) + 1:
            raise ValueError(
                f"{len(self.shards)} shards need {len(self.shards) + 1} "
                f"boundaries, got {len(self.offsets)}")
        for sh in self.shards[1:]:
            if sh.params != self.shards[0].params:
                raise ValueError("shards must share MultiStageParams")
        self.params = self.shards[0].params
        self.n_shards = len(self.shards)
        self.n_docs = int(self.offsets[-1])
        self._lock = threading.Lock()
        self._live_mut = threading.Lock()
        self._plans: dict = {}
        self.pipeline_stats = PipelineStats()
        # gather concurrency capped at the core count: more threads than
        # cores just thrash the GIL between the gathers' Python segments
        # (measured 2x slower at 4 shards on 2 cores) without adding
        # page-fault streams the machine could actually service
        workers = min(self.n_shards, max(1, os.cpu_count() or 1))
        self._pool = pool or ThreadPoolExecutor(
            max_workers=workers, thread_name_prefix="shard")
        self.set_splade_backend(self.params.splade_backend)
        self.set_rerank_backend(self.params.rerank_backend)

    # ------------------------------------------------------------------
    # group-wide knobs
    # ------------------------------------------------------------------
    def set_splade_backend(self, backend: str):
        """Switch every shard's stage-1 scorer (plans are keyed on the
        backend, so the next ``compile_plan`` recompiles)."""
        for sh in self.shards:
            sh.set_splade_backend(backend)
        self.splade_backend = backend

    def set_rerank_backend(self, backend: str):
        """Switch every shard's stage-4 tail. The *multi-shard* plans
        below keep the split tail structure regardless: the hybrid
        normaliser needs per-query statistics over the full cross-shard
        candidate list and the merge fuses need each shard's narrow
        score slice, so there is no single-dispatch tail to collapse
        into. Per-shard retrievers still honour the knob (their own
        plans are fused), and ``n_shards == 1`` delegates
        ``compile_plan`` wholesale — the one-shard group inherits the
        fused tail bitwise."""
        for sh in self.shards:
            sh.set_rerank_backend(backend)
        # group-level plans are split-shaped; record the shards' actual
        # (possibly Pallas-degraded) resolution for the cache key
        self.rerank_backend = self.shards[0].rerank_backend

    def splade_device_cache(self):
        """Materialise every shard's padded-postings device cache (each
        on its shard's device when one was assigned)."""
        return [sh.splade_device_cache() for sh in self.shards]

    def run_splade_batch(self, term_ids, term_weights, k=None,
                         backend=None, _record=True):
        """Group-wide stage 1: per-shard scoring + global merge. Kept
        for API completeness (benchmarks poke stage 1 directly); the
        serving paths go through the compiled plans."""
        k = self.params.first_k if k is None else k
        outs = list(self._pool.map(
            lambda i: self.shards[i].run_splade_batch(
                term_ids, term_weights, k, backend=backend,
                _record=_record),
            range(self.n_shards)))
        pids = np.concatenate(
            [np.where(p >= 0, p + self.offsets[i], -1)
             for i, (p, _) in enumerate(outs)], axis=1)
        scores = np.concatenate([s for _, s in outs], axis=1)
        live = self.live
        # n_shards == 1 shares the live object with its single shard,
        # whose own live path already merged the delta — skip it here
        if self.n_shards > 1 and live is not None and live.n_delta:
            d_pids, d_scores = live.splade_delta_topk(
                list(term_ids), list(term_weights), k)
            pids = np.concatenate([pids, d_pids], axis=1)
            scores = np.concatenate([scores, d_scores], axis=1)
        return merge_topk(pids, scores, k, pad_score=0.0)

    # ------------------------------------------------------------------
    # search entry points (n_shards == 1 delegates: bitwise-unsharded)
    # ------------------------------------------------------------------
    def search(self, method, q_emb=None, term_ids=None, term_weights=None,
               alpha=None, k=None):
        if self.n_shards == 1:
            return self.shards[0].search(
                method, q_emb=q_emb, term_ids=term_ids,
                term_weights=term_weights, alpha=alpha, k=k)
        wrap = (lambda x: None if x is None else [x])
        pids, scores = self.search_batch(
            method, q_embs=wrap(q_emb), term_ids=wrap(term_ids),
            term_weights=wrap(term_weights), alpha=alpha, k=k)
        return pids[0], scores[0]

    def search_batch_ctx(self, method, q_embs=None, term_ids=None,
                         term_weights=None, alpha=None, k=None, ctxs=None):
        # search_batch is inherited: it routes through here, so the
        # one-shard delegation (and its ctx threading) lands once
        if self.n_shards == 1:
            return self.shards[0].search_batch_ctx(
                method, q_embs=q_embs, term_ids=term_ids,
                term_weights=term_weights, alpha=alpha, k=k, ctxs=ctxs)
        return super().search_batch_ctx(method, q_embs=q_embs,
                                        term_ids=term_ids,
                                        term_weights=term_weights,
                                        alpha=alpha, k=k, ctxs=ctxs)

    def compile_plan(self, method: str) -> StagePlan:
        if self.n_shards == 1:
            return self.shards[0].compile_plan(method)
        return super().compile_plan(method)

    def attach_caches(self, caches):
        """Group-level caches only: the *merged* stage-1 rows are what
        get cached (shard-local rows carry shard-relative pids and must
        never alias the group's keys). With one shard every plan is
        delegated wholesale, so the caches follow the delegation."""
        self._caches = caches
        if self.n_shards == 1:
            self.shards[0].attach_caches(caches)

    def bump_index_generation(self):
        gen = super().bump_index_generation()
        for sh in self.shards:
            sh.index_generation = gen
        return gen

    def _plaid_salt(self) -> str:
        sp = self.shards[0].searcher.params
        return f"np{sp.nprobe}|cc{sp.candidate_cap}|nd{sp.ndocs}"

    # ------------------------------------------------------------------
    # live (mutable) index over the shard group
    # ------------------------------------------------------------------
    # Groups never take the unsharded inline-overlay route — the live
    # state is injected into the shared merge/fuse bodies at call time,
    # so per-shard plans stay frozen.
    _live_inline = False

    def enable_live(self):
        """Attach group-level live state. The delta segment and the
        tombstone set live at the coordinator; each shard retriever gets
        a :class:`~repro.index.live.LiveView` holding its own (local)
        tombstones so its SPLADE stage excludes them pre-top-k."""
        if self.live is not None:
            return self.live
        if self.n_shards == 1:
            self.live = self.shards[0].enable_live()
            return self.live
        if self.shards[0].searcher.device_resident:
            raise ValueError("live index requires the host (mmap) tier; "
                             "device_resident pools are frozen")
        from repro.index.live import LiveIndexState, LiveView
        live = LiveIndexState(self.shards[0].searcher.index,
                              self.shards[0].splade)
        # geometry is replicated across shards; the pid space is the
        # group's — new docs append past the last boundary
        live.base_n = self.n_docs
        for sh in self.shards:
            sh.live = LiveView()
        self.live = live
        return live

    def _sync_shard_view(self, j: int):
        lo, hi = int(self.offsets[j]), int(self.offsets[j + 1])
        self.shards[j].live.update(self.live.local_tombstones(lo, hi),
                                   generation=self.index_generation)

    def live_delete(self, gpid: int) -> bool:
        live = self._require_live()
        with self._live_mut:
            ok = live.delete(gpid)
            if not ok:
                return False
            gpid = int(gpid)
            if self.n_shards > 1 and gpid < live.base_n:
                j = int(np.searchsorted(self.offsets, gpid,
                                        side="right") - 1)
                self._sync_shard_view(j)
            self.bump_index_generation()
        return True

    def compact_live(self):
        """Merge the delta prefix into the **last** shard: delta doc j's
        global pid ``base_n + j`` already equals ``offsets[-1] + j``, so
        appending to the last shard's layout preserves every pid. The
        build runs off-gate; the swap (replace ``shards[-1]``, grow the
        boundary, rebase, bump) drains readers under the write gate."""
        if self.n_shards == 1:
            out = self.shards[0].compact_live()
            self.index_generation = self.shards[0].index_generation
            if out is not None:
                # mirror the grown layout (and drop plan closures built
                # over the pre-swap store's access stats)
                self.offsets[-1] += out["compacted"]
                self.n_docs = int(self.offsets[-1])
                with self._lock:
                    self._plans.clear()
            return out
        live = self._require_live()
        with self._live_mut:
            n_take = live.snapshot_delta()
            if n_take == 0:
                return None
            from repro.core.plaid import PLAIDSearcher
            from repro.index import live as live_mod
            from repro.index.builder import ColBERTIndex
            from repro.index.live import LiveView
            from repro.index.splade_index import SpladeIndex
            last = self.shards[-1]
            idx = last.searcher.index
            gen = self.index_generation + 1
            col_dir = idx.path.with_name(f"{idx.path.name}.g{gen}")
            spl_dir = idx.path.with_name(f"splade.g{gen}")
            live_mod.compact_colbert_dir(idx, live, n_take, col_dir)
            live_mod.compact_splade_dir(last.splade, live, n_take, spl_dir)
            new_searcher = PLAIDSearcher(
                ColBERTIndex(col_dir, mode=idx.store.mode),
                last.searcher.params, device_resident=False)
            new_retr = MultiStageRetriever(
                SpladeIndex.load(spl_dir), new_searcher,
                device=getattr(last, "device", None), params=self.params)
            new_retr.set_splade_backend(self.splade_backend)
            new_retr.set_rerank_backend(last.rerank_backend)
            with live.gate.write():
                j = self.n_shards - 1
                self.shards[j] = new_retr
                self.offsets[j + 1] += n_take   # plan closures see this
                self.n_docs = int(self.offsets[-1])
                with self._lock:
                    self._plans.clear()
                live.rebase(n_take)
                new_retr.live = LiveView()
                self._sync_shard_view(j)
                self.bump_index_generation()
        return {"compacted": n_take, "colbert_dir": str(col_dir),
                "splade_dir": str(spl_dir)}

    # ------------------------------------------------------------------
    # sharded stage plans
    # ------------------------------------------------------------------
    def _build_plan(self, method: str) -> StagePlan:
        """Compile the scatter-gather stage graph for one method.

        Stage discipline matches the unsharded plans (host stages touch
        only numpy; device dispatches and syncs live in device-kind
        stages), with two additions: per-shard stages carry
        ``fanout=n_shards`` and read/write the batch's shard axis, and
        ``merge_topk`` fuses run on the host over already-synced per-
        shard arrays."""
        p = self.params
        S = self.n_shards
        offs = self.offsets
        shards = self.shards
        dr = shards[0].searcher.device_resident
        gather_kind = DEVICE if dr else HOST
        access = None if dr else CombinedAccessStats(
            [sh.searcher.index.store.stats for sh in shards])
        ndocs = min(shards[0].searcher.params.ndocs,
                    shards[0].searcher.params.candidate_cap)

        if method == "colbert":
            from repro.core.plaid import (
                pad_query_batch,
                stage1_centroid_probe_batch,
                stage2_candidates_batch,
            )

            def probe(cb):
                # ONE centroid probe for the whole group: the centroid
                # set is replicated (geometry, not corpus), so a
                # per-shard probe would duplicate the einsum S times
                # for identical results
                sr = shards[0].searcher
                q, q_valid = pad_query_batch(cb.q_embs)
                B, q, q_valid = _pad_batch_rows(q, q_valid)
                scores_c, cids = stage1_centroid_probe_batch(
                    q, q_valid, sr.centroids, sr.params.nprobe)
                return cb.with_state(B=B, q=q, q_valid=q_valid,
                                     scores_c=scores_c, cids=cids)

            def candidates(cb, i):
                # per-shard candidate generation from the shard's IVF
                # slice; narrowed to the densest row's pow2 bucket (the
                # -1 fill is already compacted to the back) so the
                # codes gather and approx dispatch run at the shard's
                # ~cap/S occupancy, not the full global cap
                sr = shards[i].searcher
                cand = stage2_candidates_batch(
                    sr.ivf_padded, cb.state["cids"],
                    sr.params.candidate_cap)
                cand_np = np.asarray(cand)
                n_real = (cand_np >= 0).sum(axis=1)
                W = min(_next_pow2(max(int(n_real.max()), 8)),
                        cand_np.shape[1])
                return {"cand": cand[:, :W], "cand_np": cand_np[:, :W],
                        "n_real": n_real}

            def gather_codes(cb, i):
                s = dict(cb.shard_states[i])
                if dr:
                    codes, valid = shards[i].searcher.gather_codes_batch(
                        s["cand"])
                else:
                    codes, _, valid = shards[i].searcher._dedup_gather(
                        s["cand_np"], codes_only=True)
                s.update(codes=codes, cvalid=valid)
                return s

            def approx(cb, i):
                # raw approximate scores, NOT a per-shard top-ndocs:
                # survivor selection must be global or a shard-local
                # ndocs cut would diverge from the single-index path
                s = dict(cb.shard_states[i])
                a = stage3_approx_score_batch(
                    cb.state["scores_c"], jnp.asarray(s["codes"]),
                    jnp.asarray(s["cvalid"]), cb.state["q_valid"])
                a = jnp.where(s["cand"] >= 0, a, -jnp.inf)
                s["approx_np"] = np.asarray(a)
                return s

            def merge_approx(cb):
                # live is read at call time: plans compiled before
                # enable_live (or before the first mutation) stay valid
                return merge_approx_state(cb, offs, ndocs, live=self.live)

            def gather_residuals(cb, i):
                s = dict(cb.shard_states[i])
                cols, sel = compact_owned(cb.state["final_g"],
                                          offs[i], offs[i + 1])
                if dr:
                    f_codes, f_packed, f_valid = \
                        shards[i].searcher.gather_tokens_batch(sel)
                else:
                    f_codes, f_packed, f_valid = \
                        shards[i].searcher._dedup_gather(
                            sel, codes_only=False)
                s.update(cols=cols, sel=sel, f_codes=f_codes,
                         f_packed=f_packed, f_valid=f_valid)
                return s

            def exact(cb, i):
                s = dict(cb.shard_states[i])
                st = cb.state
                ex = shards[i].searcher.exact_score_gathered(
                    st["q"], st["q_valid"], jnp.asarray(s["f_codes"]),
                    jnp.asarray(s["f_packed"]), jnp.asarray(s["f_valid"]),
                    jnp.asarray(s["sel"]))
                s["exact_np"] = np.asarray(ex)   # (Bp, W_i) narrow slice
                return s

            stages = (
                Stage("plaid_probe", DEVICE, probe),
                Stage("plaid_probe:ivf", DEVICE, candidates, fanout=S),
                Stage("host_gather:codes", gather_kind, gather_codes,
                      fanout=S, pooled=not dr),
                Stage("device_score:approx", DEVICE, approx, fanout=S),
                Stage("merge_topk:approx", HOST, merge_approx),
                Stage("host_gather:residuals", gather_kind,
                      gather_residuals, fanout=S, pooled=not dr),
                Stage("device_score:exact", DEVICE, exact, fanout=S),
                Stage("merge_topk", HOST,
                      lambda cb: fuse_colbert_state(cb, live=self.live)))
            return StagePlan(method=method, stages=stages,
                             access_stats=access, pool=self._pool)

        s1_kind = HOST if self.splade_backend == "host" else DEVICE
        backend = self.splade_backend

        def splade_stage(cb):
            """Group stage 1, writing the shard axis itself. On the
            device backends every shard's dispatch is issued *before*
            any sync (``dispatch_topk``/``finalize_topk``), so with
            per-shard device pinning the accelerators score their
            postings slices concurrently — a per-shard sync loop would
            serialise them behind the first shard's result."""
            cached = self._stage1_group_lookup(cb)
            if cached is not None:
                # merged rows for every query are cached: skip the
                # per-shard fanout; the merge stage rebuilds state
                return cb.with_state(stage1_cached=cached)
            tids, tw = list(cb.term_ids), list(cb.term_weights)
            live = self.live
            if backend == "host" or (live is not None and live.dirty):
                # a dirty live state forces the host stage-1: the shard
                # retrievers' live views apply tombstone exclusion
                # pre-top-k there (the device scorers have no exclusion
                # path), matching the unsharded live rule
                outs = [sh.run_splade_batch(tids, tw, p.first_k,
                                            _record=False)
                        for sh in shards]
            else:
                impl = shards[0]._splade_impl(backend)
                disps = [sh.splade_device_cache().dispatch_topk(
                    tids, tw, p.first_k, impl=impl) for sh in shards]
                outs = [sh.splade_device_cache().finalize_topk(d)
                        for sh, d in zip(shards, disps)]
            return cb.evolve(shard_states=tuple(
                {"pids": np.where(pd >= 0, pd + offs[i], -1),
                 "scores": sc}
                for i, (pd, sc) in enumerate(outs)))

        def fuse_splade(cb):
            cached = cb.state.get("stage1_cached")
            if cached is not None:
                pids_b, s_scores = cached
                return cb.evolve(pids=pids_b[:, :cb.k],
                                 scores=s_scores[:, :cb.k])
            cb = fuse_splade_state(cb, p.first_k, live=self.live)
            self._stage1_group_store(cb)
            return cb

        if method == "splade":
            stages = (Stage("splade_stage1", s1_kind, splade_stage),
                      Stage("merge_topk", HOST, fuse_splade))
            return StagePlan(method=method, stages=stages,
                             access_stats=access, pool=self._pool)

        # rerank / hybrid: merged SPLADE candidates → shard-parallel
        # residual gather → per-shard MaxSim → global fuse (+ α)
        def merge_stage1(cb):
            cached = cb.state.get("stage1_cached")
            if cached is not None:
                return stage1_state_from_rows(cb, *cached)
            cb = merge_stage1_state(cb, p.first_k, live=self.live)
            self._stage1_group_store(cb)
            return cb

        def gather(cb, i):
            st = cb.state
            cols, sel = compact_owned(st["gp"], offs[i], offs[i + 1])
            if dr:
                codes, packed, valid = \
                    shards[i].searcher.gather_tokens_batch(sel)
            else:
                codes, packed, valid = shards[i].searcher._dedup_gather(
                    sel, codes_only=False)
            return {"cols": cols, "sel": sel, "g_codes": codes,
                    "g_packed": packed, "g_valid": valid}

        def score(cb, i):
            s = dict(cb.shard_states[i])
            st = cb.state
            s["c_dev"] = shards[i].searcher.score_gathered_lazy(
                jnp.asarray(st["q"]), jnp.asarray(st["q_valid"]),
                jnp.asarray(s["g_codes"]), jnp.asarray(s["g_packed"]),
                jnp.asarray(s["g_valid"]), s["sel"])[:st["B"]]
            return s

        def fuse_rerank(cb):
            # sync each shard's narrow lazy score slice and scatter it
            # back into the global candidate columns
            return fuse_scatter_rerank(cb, method, p.normalizer,
                                       live=self.live)

        stages = (Stage("splade_stage1", s1_kind, splade_stage),
                  Stage("merge_topk:stage1", HOST, merge_stage1),
                  Stage("host_gather:residuals", gather_kind, gather,
                        fanout=S, pooled=not dr),
                  Stage("device_score:maxsim", DEVICE, score, fanout=S,
                        opens_async=True),
                  Stage("fuse_topk", DEVICE, fuse_rerank,
                        closes_async=True))
        return StagePlan(method=method, stages=stages,
                         access_stats=access, pool=self._pool)


def build_sharded_retriever(shard_dirs, boundaries, *, mode: str = "mmap",
                            plaid_params=None, multistage_params=None,
                            devices: Optional[Sequence] = None
                            ) -> ShardedRetriever:
    """Load a shard group written by ``split_index_tree`` into a
    :class:`ShardedRetriever`. ``shard_dirs``: per-shard directories
    each holding ``colbert/`` + ``splade/``; ``devices`` optionally
    pins shard i's device-resident state (SPLADE device cache) to
    ``devices[i]`` — see ``launch.mesh.shard_device_map``."""
    from repro.core.plaid import PLAIDSearcher, PlaidParams
    from repro.index.builder import ColBERTIndex
    from repro.index.splade_index import SpladeIndex

    plaid_params = plaid_params or PlaidParams()
    shards = []
    for i, d in enumerate(shard_dirs):
        d = pathlib.Path(d)
        index = ColBERTIndex(d / "colbert", mode=mode)
        sidx = SpladeIndex.load(d / "splade", mmap=(mode == "mmap"))
        searcher = PLAIDSearcher(index, plaid_params)
        kw = {} if multistage_params is None \
            else {"params": multistage_params}
        retr = MultiStageRetriever(
            sidx, searcher,
            device=None if devices is None else devices[i], **kw)
        shards.append(retr)
    return ShardedRetriever(shards, boundaries)


# ---------------------------------------------------------------------------
# process-group backend: shared-nothing shard workers over RPC
# ---------------------------------------------------------------------------

#: Write ops mutate worker state, so the pure-op recovery machinery is
#: off-limits for them: hedging would race two applications of the same
#: write, and sibling failover could double-apply one that half-landed
#: on the failed replica. The dispatcher surfaces their failures to the
#: caller instead.
MUTATION_OPS = frozenset({"live_sync", "live_reload"})


class _Slot:
    """One logical RPC enqueued on a :class:`_ShardDispatcher`; resolves
    to either its own reply or its slice of a coalesced ``multi``
    reply. ``replica`` records which replica the flush landed on so the
    waiter can attribute success/failure and fail over to a sibling."""

    __slots__ = ("op", "payload", "cli", "rep", "index", "error",
                 "replica")

    def __init__(self, op: str, payload):
        self.op = op
        self.payload = payload
        self.cli = None
        self.rep = None               # None until flushed to the wire
        self.index = None             # position inside a multi dispatch
        self.error = None
        self.replica = None


class _ShardDispatcher:
    """Per-worker RPC coalescer: one dispatch per worker per stage.

    ``enqueue`` flushes immediately when the worker is idle (it should
    start computing as early as possible), and *buffers* while the
    worker has outstanding work — the worker serves FIFO one op at a
    time, so buffering behind an in-flight op costs zero worker idle,
    and every op that accumulates meanwhile rides the next flush as one
    ``multi`` frame (one encode, one send, one wakeup) instead of N.
    ``wait`` flushes anything still buffered — a slot can never
    strand — and demuxes per-op ok/error slices so one bad micro-batch
    doesn't poison its co-batched neighbours. Replies stay FIFO per
    connection, so the client's pipelined stream discipline is
    untouched."""

    def __init__(self, group: "ProcessShardGroup", index: int):
        self.group = group
        self.i = index
        self._lock = threading.Lock()
        self._buf: list = []
        self._last_cli = None
        self._last: dict = {}

    def enqueue(self, op: str, payload) -> _Slot:
        slot = _Slot(op, payload)
        with self._lock:
            replica, cli = self.group._route(self.i)  # fails fast dead
            self._buf.append(slot)
            if cli.outstanding() == 0:
                self._flush_locked(replica, cli)
        return slot

    def _flush_locked(self, replica, cli):
        from repro.serving.transport import ShardWorkerDied

        if not self._buf:
            return
        slots, self._buf = self._buf, []
        stats = self.group.pipeline_stats
        deadline_ms = self.group.op_deadline_ms
        try:
            if len(slots) == 1:
                s = slots[0]
                s.cli, s.rep = cli, cli.call_async(
                    s.op, s.payload, timeout_ms=deadline_ms)
                s.replica = replica
            else:
                rep = cli.call_async("multi", {"ops": [
                    {"op": s.op, "payload": s.payload} for s in slots]},
                    timeout_ms=deadline_ms)
                for j, s in enumerate(slots):
                    s.cli, s.rep, s.index = cli, rep, j
                    s.replica = replica
                stats.counter("rpc_coalesced_ops", len(slots) - 1)
        except ShardWorkerDied as e:
            # send failure (dead socket, injected fault): the client is
            # already marked dead. Park the error on every co-batched
            # slot instead of raising — waiters surface it inside their
            # failover handling, so multi-replica sets retry siblings
            # and single-replica sets raise at wait time as before.
            for s in slots:
                if s.rep is None:
                    s.error = e
                    s.replica = replica
            if replica is not None:
                self.group._replica_sets[self.i].record_failure(replica)
            return
        except BaseException as e:
            # non-connection failure: fan it out to every co-batched
            # slot (their waiters must fail, not re-flush an empty
            # buffer forever) and propagate
            for s in slots:
                if s.rep is None:
                    s.error = e
                    s.replica = replica
            raise
        stats.counter("rpc_dispatches")
        for s in slots:
            stats.counter(f"rpc_ops:{s.op}")
        self._account(cli)

    def _account(self, cli):
        """Mirror the channel's monotonic byte counters into
        PipelineStats as deltas (a respawned client restarts at 0)."""
        ts = cli.transport_stats()
        if cli is not self._last_cli:
            self._last_cli, self._last = cli, {}
        for key in ("bytes_sent", "bytes_recv", "bytes_copied",
                    "bytes_zero_copy"):
            delta = ts[key] - self._last.get(key, 0)
            if delta > 0:
                self.group.pipeline_stats.counter(
                    f"transport_{key}", delta)
            self._last[key] = ts[key]

    def wait(self, slot: _Slot):
        from repro.serving.replica import _Straggler
        from repro.serving.transport import (DeadlineExceeded,
                                             ShardWorkerDied)

        if slot.rep is None and slot.error is None:
            with self._lock:
                if slot.rep is None and slot.error is None:
                    replica, cli = self.group._route(self.i)
                    self._flush_locked(replica, cli)
        g = self.group
        try:
            if slot.error is not None:
                raise slot.error
            out = g._wait_replica(self.i, slot)
            with self._lock:
                self._account(slot.cli)
            if slot.index is None:
                return out
            sub = out["replies"][slot.index]
            if not sub.get("ok", False):
                from repro.serving.transport import ShardWorkerError
                raise ShardWorkerError(
                    f"shard {self.i} op {slot.op!r} failed:\n"
                    f"{sub.get('error')}")
            return sub.get("result")
        except _Straggler:
            # the replica is merely slow: give up on it past the hedge
            # budget and re-run the op on a sibling (safe — shard ops
            # are pure; mutation ops never arm the budget, see
            # ``_wait_replica``). The straggler's reply stays pending on
            # its own connection; FIFO discipline consumes it later
            # without desequencing.
            g.pipeline_stats.counter("hedges")
            out = g._resend_slot(self.i, slot)
            g.pipeline_stats.counter("hedge_wins")
            return out
        except (ShardWorkerDied, DeadlineExceeded) as e:
            if (slot.op in MUTATION_OPS
                    or g._replica_sets[self.i].total == 1):
                # mutations must not fail over (retry could double-
                # apply); single-replica keeps legacy heal-on-next-use
                raise
            g.pipeline_stats.counter("failover_retries")
            return g._resend_slot(self.i, slot, last_error=e)

    def call(self, op: str, payload):
        return self.wait(self.enqueue(op, payload))


class ProcessShardGroup(MultiStageRetriever):
    """Scatter-gather retriever whose shards are **separate OS
    processes** (``repro.serving.worker``), one per ``shards/<i>/``
    subtree, talked to over the layered ``repro.serving.transport``
    stack — shared-memory ring arenas (``transport="shm"``, tensor
    bytes cross zero-copy) or a socketpair stream (``"socket"``,
    portable), with per-worker RPC coalescing: ops that land on a busy
    worker ride the next flush as one ``multi`` frame, one dispatch per
    worker per stage across co-batched micro-batches.

    Shared-nothing is the point: each worker owns its mmap
    ``PagedStore`` segment (its *own page-cache working set* — the
    aggregate pool is split across processes, not replicated), its own
    SPLADE postings slice / device cache, and its own GIL, so per-shard
    gathers and kernels run truly concurrently on multi-core hosts —
    the regime where mmap scoring wins.

    Parity contract: workers execute the *same stage functions over the
    same inputs* as the in-process thread backend (the RPC codec is
    lossless for numpy dtypes), and every coordinator-side merge/fuse
    is the same shared function (:func:`merge_stage1_state`,
    :func:`fuse_scatter_rerank`, :func:`merge_approx_state`,
    :func:`fuse_colbert_state`) — so ``--shard-workers=process`` is
    bitwise-identical to ``--shard-workers=thread`` and therefore to
    ``shards=1``.

    Pipelining/backpressure: per-shard ``score`` dispatches are split
    into an ``opens_async`` send stage and a ``closes_async`` wait
    stage, so the executor's software pipelining parks a batch while
    its workers compute and runs the next batch's host stages — the
    same overlap semantics as lazy device dispatch, across a process
    boundary. Each in-flight micro-batch holds at most one outstanding
    RPC per worker, so the executor's admission semaphore bounds the
    RPC queue on every worker.

    Lifecycle: spawn-all at construction (first ping is the readiness
    barrier), heartbeat via :meth:`worker_health`, graceful SIGTERM
    drain (:meth:`close` escalates shutdown-RPC → SIGTERM → SIGKILL and
    always reaps — no orphans). A crashed worker fails its in-flight
    batch with :class:`~repro.serving.rpc.ShardWorkerDied` and is
    respawned on next use (single-restart healing: a worker that dies
    again before serving one successful call is not respawned)."""

    def __init__(self, shard_dirs, boundaries, *, mode: str = "mmap",
                 plaid_params=None, multistage_params=None,
                 spawn_timeout_s: float = 300.0,
                 call_timeout_s: float = 300.0,
                 worker_env: Optional[dict] = None,
                 transport: Optional[str] = None,
                 arena_bytes: Optional[int] = None,
                 replicas: int = 1,
                 replica_endpoints=None,
                 allow_degraded: bool = False,
                 op_deadline_ms: Optional[float] = None,
                 hedge_factor: float = 0.0,
                 hedge_floor_ms: float = 50.0,
                 failover_backoff_ms: float = 10.0,
                 fault_spec=None,
                 autostart: bool = True):
        from repro.core.plaid import PlaidParams
        from repro.launch.mesh import (default_shard_transport,
                                       shard_arena_bytes)
        from repro.serving.replica import ReplicaSet, _Replica
        from repro.serving.transport import FaultSpec

        self.shard_dirs = [str(d) for d in shard_dirs]
        if not self.shard_dirs:
            raise ValueError("empty shard group")
        self.offsets = np.asarray(boundaries, np.int64)
        if len(self.offsets) != len(self.shard_dirs) + 1:
            raise ValueError(
                f"{len(self.shard_dirs)} shards need "
                f"{len(self.shard_dirs) + 1} boundaries, "
                f"got {len(self.offsets)}")
        self.n_shards = len(self.shard_dirs)
        self.n_docs = int(self.offsets[-1])
        self.mode = mode
        self.plaid_params = plaid_params or PlaidParams()
        self.params = multistage_params or MultiStageParams()
        self.spawn_timeout_s = spawn_timeout_s
        self.call_timeout_s = call_timeout_s
        self.transport = transport or default_shard_transport()
        if self.transport not in ("shm", "socket"):
            raise ValueError(
                f"shard transport {self.transport!r} not in "
                f"('shm', 'socket')")
        self.arena_bytes = shard_arena_bytes(self.n_shards, arena_bytes)
        if worker_env is None:
            from repro.launch.mesh import shard_worker_env
            worker_env = shard_worker_env(self.n_shards)
        self._worker_env = worker_env
        self.allow_degraded = bool(allow_degraded)
        self.op_deadline_ms = op_deadline_ms
        self.failover_backoff_ms = float(failover_backoff_ms)
        self.fault_spec = (FaultSpec.parse(fault_spec)
                           if isinstance(fault_spec, str) else fault_spec)
        # replica axis: `replicas` local child workers per shard plus
        # any remote standalone endpoints; replicas[0] is the primary
        # slot the legacy single-replica semantics bind to
        n_local = int(replicas)
        endpoints = self._normalize_endpoints(replica_endpoints)
        if n_local < 0:
            raise ValueError(f"replicas {n_local} < 0")
        self._replica_sets = []
        for i in range(self.n_shards):
            reps = [_Replica(i, rid, self._client_factory(i, None))
                    for rid in range(n_local)]
            reps += [_Replica(i, n_local + j,
                              self._client_factory(i, ep), endpoint=ep)
                     for j, ep in enumerate(endpoints[i])]
            if not reps:
                raise ValueError(
                    f"shard {i} has no replicas (replicas=0 and no "
                    f"replica_endpoints entry)")
            self._replica_sets.append(ReplicaSet(
                i, reps, hedge_factor=hedge_factor,
                hedge_floor_ms=hedge_floor_ms))
        self._lock = threading.Lock()
        self._live_mut = threading.Lock()
        self._plans: dict = {}
        self.pipeline_stats = PipelineStats()
        total_replicas = sum(rs.total for rs in self._replica_sets)
        self._pool = ThreadPoolExecutor(
            max_workers=max(self.n_shards, total_replicas),
            thread_name_prefix="shard-rpc")
        self._disp = [_ShardDispatcher(self, i)
                      for i in range(self.n_shards)]
        self._closed = False
        self._healer = None
        self._heal_wake = threading.Event()
        self._centroids_cache = None
        self.set_splade_backend(self.params.splade_backend)
        # group plans are split-shaped (cross-process merges need each
        # worker's narrow score slice); the knob still resolves so the
        # plan-cache key and health snapshots stay uniform
        self.set_rerank_backend(self.params.rerank_backend)
        if autostart:
            self.start()

    def _normalize_endpoints(self, replica_endpoints):
        """Per-shard remote endpoint lists. Accepts None, a compact
        string (``;`` between shards, ``,`` between a shard's
        replicas), or an already-parsed sequence of sequences."""
        if replica_endpoints is None:
            return [[] for _ in range(self.n_shards)]
        if isinstance(replica_endpoints, str):
            parts = [p for p in replica_endpoints.split(";")]
            out = [[e.strip() for e in p.split(",") if e.strip()]
                   for p in parts]
        else:
            out = [list(p) for p in replica_endpoints]
        if len(out) != self.n_shards:
            raise ValueError(
                f"replica_endpoints covers {len(out)} shards, group "
                f"has {self.n_shards}")
        return out

    def _client_factory(self, i: int, endpoint):
        """Factory building an unspawned client for shard ``i`` at a
        given arena generation (a locator minted against a dead
        worker's arena can never resolve against the new one)."""
        import dataclasses as _dc

        def factory(generation: int):
            from repro.serving.rpc import ShardWorkerClient
            return ShardWorkerClient(
                i, self.shard_dirs[i], mode=self.mode,
                plaid_params=_dc.asdict(self.plaid_params),
                ms_params=_dc.asdict(self.params),
                env=self._worker_env,
                spawn_timeout_s=self.spawn_timeout_s,
                call_timeout_s=self.call_timeout_s,
                transport=self.transport,
                arena_bytes=self.arena_bytes,
                generation=generation,
                endpoint=endpoint,
                fault_spec=self.fault_spec)
        return factory

    # -- legacy single-replica views -----------------------------------
    @property
    def _clients(self) -> list:
        """Primary-replica clients, one per shard (the legacy view;
        sibling replicas live on ``_replica_sets``)."""
        return [rs.primary.client for rs in self._replica_sets]

    @property
    def restarts(self) -> list:
        return [rs.primary.restarts for rs in self._replica_sets]

    # ------------------------------------------------------------------
    # worker lifecycle
    # ------------------------------------------------------------------
    def start(self):
        """Spawn/connect every replica of every shard concurrently;
        returns after each one's readiness ping (jax imported, shard
        subtree mapped / remote worker answered). A replica that fails
        to come up tears the whole group down — a partially spawned
        group would leak the workers that did start."""
        def up(r):
            if r is self._replica_sets[r.shard_index].primary:
                return self._ensure_worker(r.shard_index)
            return r.ensure(fail_fast=False)

        try:
            list(self._pool.map(
                up, [r for rs in self._replica_sets
                     for r in rs.replicas]))
        except BaseException:
            self.close(grace_s=1.0)
            raise
        self._start_healer()
        return self

    def _ensure_worker(self, i: int):
        """Live *primary* client for shard ``i`` — the legacy
        single-replica contract, spawn-locked per replica so concurrent
        stages racing into a dead shard act exactly once.

        Crash discipline: a corpse discovered here is reaped and the
        discovering call **fails fast** with a clear
        :class:`~repro.serving.rpc.ShardWorkerDied` — a serving batch
        must not silently absorb a multi-second worker respawn. The
        *next* call respawns (heal-on-restart). A worker that dies
        again before serving one successful call — or that fails to
        spawn twice in a row — is quarantined (no respawn loop); a
        later successful call resets both budgets."""
        from repro.serving.rpc import ShardWorkerDied

        primary = self._replica_sets[i].primary
        with primary.lock:
            if self._closed:
                raise ShardWorkerDied(
                    f"shard group closed; shard {i} unavailable")
            return primary.ensure(fail_fast=True)

    def _route(self, i: int):
        """(replica, live client) to dispatch shard ``i``'s next frame
        on. Single-replica sets keep the legacy fail-fast primary path
        verbatim; multi-replica sets route fastest-healthy-first."""
        from repro.serving.rpc import ShardWorkerDied

        rs = self._replica_sets[i]
        if rs.total == 1:
            return rs.primary, self._ensure_worker(i)
        if self._closed:
            raise ShardWorkerDied(
                f"shard group closed; shard {i} unavailable")
        return rs.acquire()

    def _wait_replica(self, i: int, slot):
        """Wait one dispatched slot with health accounting. Raises
        ``_Straggler`` when a hedge budget expires with the reply still
        outstanding (the dispatcher re-sends on a sibling)."""
        from repro.serving.replica import _Straggler
        from repro.serving.transport import (DeadlineExceeded,
                                             ShardWorkerDied,
                                             ShardWorkerError)

        rs = self._replica_sets[i]
        r = slot.replica
        # mutation ops never arm the hedge budget: a straggling write
        # must be waited out, not re-sent to a sibling
        budget_ms = (None if slot.op in MUTATION_OPS
                     else rs.hedge_budget_ms(r))
        t0 = time.monotonic()
        try:
            if budget_ms is not None:
                try:
                    out = slot.cli.wait(slot.rep,
                                        timeout=budget_ms / 1e3,
                                        kill_on_timeout=False)
                except ShardWorkerError:
                    if not slot.rep.event.is_set():
                        raise _Straggler()  # slow, not failed
                    raise
            else:
                out = slot.cli.wait(slot.rep)
        except (ShardWorkerDied, DeadlineExceeded):
            if r is not None:
                rs.record_failure(r)
            raise
        if r is not None:
            rs.record_success(r, (time.monotonic() - t0) * 1e3)
        return out

    def _resend_slot(self, i: int, slot, last_error=None):
        """Re-run one slot's op on sibling replicas (exponential
        backoff + jitter between attempts). Shard ops are pure
        functions of the request, so a retry — even after a reply was
        maybe half-computed elsewhere — cannot change the answer."""
        import random as _random

        from repro.serving.transport import (DeadlineExceeded,
                                             ShardUnavailable,
                                             ShardWorkerDied,
                                             ShardWorkerError)

        if slot.op in MUTATION_OPS:
            # defense in depth behind the wait()-side guard: a write
            # may have half-applied on the failed replica, so re-running
            # it on a sibling could double-apply
            if last_error is not None:
                raise last_error
            raise ShardWorkerDied(
                f"shard {i}: mutation op {slot.op!r} is not retryable")
        rs = self._replica_sets[i]
        delay_s = self.failover_backoff_ms / 1e3
        exclude = slot.replica
        for _ in range(max(2, 2 * rs.total)):
            try:
                replica, cli = rs.acquire(exclude=exclude)
            except ShardUnavailable as e:
                # every *other* replica is unreachable right now — but
                # the excluded one (whose connection just faulted) may
                # merely need a reconnect, and a cooling sibling may
                # come back within the breaker window. Back off and let
                # the next iteration consider every replica again
                # instead of giving up while a live worker exists.
                exclude = None
                last_error = e.last_error or e
                time.sleep(delay_s * (1.0 + 0.5 * _random.random()))
                delay_s = min(delay_s * 2.0, 1.0)
                continue
            exclude = None     # after the first pick all siblings count
            t0 = time.monotonic()
            try:
                out = cli.call(slot.op, slot.payload,
                               timeout_ms=self.op_deadline_ms)
            except ShardWorkerError:
                raise          # deterministic op failure: do not retry
            except (ShardWorkerDied, DeadlineExceeded) as e:
                rs.record_failure(replica)
                last_error = e
                time.sleep(delay_s * (1.0 + 0.5 * _random.random()))
                delay_s = min(delay_s * 2.0, 1.0)
                continue
            rs.record_success(replica, (time.monotonic() - t0) * 1e3)
            return out
        raise ShardUnavailable(
            f"shard {i}: failover exhausted its retries "
            f"(last error: {last_error})", shard=i,
            last_error=last_error)

    def _degradable(self, fn):
        """Run one shard's stage op; with ``allow_degraded`` a shard
        whose every replica is gone yields None (its slot becomes a
        ``missing`` state) instead of failing the whole batch."""
        from repro.serving.transport import (DeadlineExceeded,
                                             ShardWorkerDied)

        try:
            return fn()
        except (ShardWorkerDied, DeadlineExceeded):
            if not self.allow_degraded:
                raise
            self.pipeline_stats.counter("degraded_shard_ops")
            return None

    # -- background healer ---------------------------------------------
    def _start_healer(self):
        """Replicated groups get a daemon that restores redundancy in
        the background (reconnect remote siblings, respawn local ones)
        instead of waiting for traffic to land on the dead replica.
        Single-replica groups keep the legacy heal-on-next-use path
        only — no extra thread, no behavior change."""
        if all(rs.total == 1 for rs in self._replica_sets):
            return
        self._healer = threading.Thread(target=self._healer_loop,
                                        name="shard-healer", daemon=True)
        self._healer.start()

    def _healer_loop(self):
        from repro.serving.transport import ShardWorkerDied

        while not self._closed:
            self._heal_wake.wait(1.0)
            if self._closed:
                return
            now = time.monotonic()
            for rs in self._replica_sets:
                for r in rs.replicas:
                    if self._closed:
                        return
                    if (r.is_alive() or r.quarantined()
                            or r.breaker_open_until > now):
                        continue
                    try:
                        r.ensure(fail_fast=False)
                        self.pipeline_stats.counter("replica_heals")
                    except ShardWorkerDied:
                        rs.record_failure(r)

    def _call_async(self, i: int, op: str, payload):
        cli = self._ensure_worker(i)
        return cli, cli.call_async(op, payload,
                                   timeout_ms=self.op_deadline_ms)

    def _wait(self, i: int, cli, rep):
        out = cli.wait(rep)
        rs = self._replica_sets[i]
        rs.record_success(rs.primary)         # healed / healthy
        return out

    def _call(self, i: int, op: str, payload):
        cli, rep = self._call_async(i, op, payload)
        return self._wait(i, cli, rep)

    def worker_pids(self) -> list:
        return [None if c is None else c.pid for c in self._clients]

    def heartbeat(self, timeout_s: float = 10.0) -> list:
        """Ping every worker; True per shard that answered."""
        from repro.serving.rpc import ShardWorkerDied, ShardWorkerError

        out = []
        for i, cli in enumerate(self._clients):
            if cli is None or not cli.alive():
                out.append(False)
                continue
            try:
                # soft deadline: a ping queued behind a long op must
                # not kill a busy worker
                cli.call("ping", {}, timeout=timeout_s,
                         kill_on_timeout=False)
                out.append(True)
            except (ShardWorkerDied, ShardWorkerError):
                out.append(False)
        return out

    def worker_health(self) -> list:
        """Per-worker vitals (pid, RSS, mmap segment bytes, served
        count, restart count, spawn/serve failure budgets, sibling
        replica state) — never raises, never respawns: a dead worker
        reports ``alive: False`` until traffic (or the healer thread)
        heals it."""
        from repro.serving.rpc import ShardWorkerDied, ShardWorkerError

        out = []
        for i, cli in enumerate(self._clients):
            rs = self._replica_sets[i]
            rec = {"shard": i,
                   "pid": None if cli is None else cli.pid,
                   "alive": bool(cli is not None and cli.alive()),
                   "restarts": self.restarts[i],
                   "spawn_failures": rs.primary.spawn_failures,
                   "serve_failures": rs.primary.serve_failures}
            if rs.total > 1:
                rec["replicas"] = [r.health() for r in rs.replicas]
                rec["alive_replicas"] = rs.alive_count()
            if cli is not None:
                ts = cli.transport_stats()
                rec["transport"] = ts["transport"]
                rec["rpc_bytes_sent"] = ts["bytes_sent"]
                rec["rpc_bytes_recv"] = ts["bytes_recv"]
                rec["rpc_bytes_copied"] = ts["bytes_copied"]
                rec["rpc_bytes_zero_copy"] = ts["bytes_zero_copy"]
                if cli.arena_generation is not None:
                    rec["arena_generation"] = cli.arena_generation
            if rec["alive"]:
                try:
                    # soft deadline (kill_on_timeout=False): health
                    # polls queue FIFO behind real work, and a monitor
                    # must never kill a worker that is merely busy
                    rec.update(cli.call("health", {}, timeout=10.0,
                                        kill_on_timeout=False))
                except ShardWorkerDied as e:
                    rec["alive"] = False
                    rec["error"] = str(e)
                except ShardWorkerError as e:
                    rec["busy"] = True
                    rec["error"] = str(e)
            out.append(rec)
        return out

    def transport_stats(self) -> dict:
        """Group-wide transport byte accounting: per-worker channel
        stats plus copied/zero-copy totals — how much tensor traffic
        actually bypassed serialization."""
        per, total = [], {"bytes_sent": 0, "bytes_recv": 0,
                          "bytes_copied": 0, "bytes_zero_copy": 0}
        for i, rs in enumerate(self._replica_sets):
            for r in rs.replicas:
                cli = r.client
                if cli is None:
                    continue
                ts = cli.transport_stats()
                ts["shard"] = i
                ts["replica"] = r.rid
                per.append(ts)
                for k in total:
                    total[k] += ts[k]
        return {"transport": self.transport, "per_worker": per,
                "total": total}

    def degraded_shards(self) -> list:
        """Shard ids currently served by zero live replicas — the set
        a degraded answer would be missing right now."""
        return [rs.i for rs in self._replica_sets
                if rs.alive_count() == 0]

    def close(self, grace_s: float = 5.0):
        """Graceful group shutdown: drain each worker (shutdown RPC,
        then SIGTERM, then SIGKILL) and reap every child; remote
        replicas just drop their connection (their accept loop serves
        the next coordinator). Idempotent. Takes each replica's spawn
        lock so a concurrent heal that was already past the
        closed-check finishes its spawn first and is then terminated
        here — never leaked."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._heal_wake.set()
        if self._healer is not None:
            self._healer.join(timeout=2.0)
        for rs in self._replica_sets:
            for r in rs.replicas:
                r.terminate(grace_s=grace_s)
        self._pool.shutdown(wait=False)

    # ------------------------------------------------------------------
    # retriever protocol
    # ------------------------------------------------------------------
    def search(self, method, q_emb=None, term_ids=None, term_weights=None,
               alpha=None, k=None):
        wrap = (lambda x: None if x is None else [x])
        pids, scores = self.search_batch(
            method, q_embs=wrap(q_emb), term_ids=wrap(term_ids),
            term_weights=wrap(term_weights), alpha=alpha, k=k)
        return pids[0], scores[0]

    def run_splade_batch(self, term_ids, term_weights, k=None,
                         backend=None, _record=True):
        """Group-wide stage 1 over the worker processes (benchmarks
        poke this directly; serving goes through the compiled plans)."""
        k = self.params.first_k if k is None else k
        payload = {"term_ids": list(term_ids),
                   "term_weights": list(term_weights), "k": k,
                   "backend": backend or self.splade_backend}
        slots = [self._degradable(
                     lambda i=i: self._disp[i].enqueue("splade", payload))
                 for i in range(self.n_shards)]
        outs = [None if s is None else
                self._degradable(lambda i=i, s=s: self._disp[i].wait(s))
                for i, s in enumerate(slots)]
        live, _ = _live_shard_states(tuple(
            {"missing": True} if r is None else r for r in outs))
        pids = np.concatenate(
            [np.where(r["pids"] >= 0, r["pids"] + self.offsets[i], -1)
             for i, r in live], axis=1)
        scores = np.concatenate([r["scores"] for _, r in live], axis=1)
        return merge_topk(pids, scores, k, pad_score=0.0)

    def splade_device_cache(self):
        """Warm every worker's padded-postings device cache for the
        current stage-1 backend (no-op per worker on ``host``)."""
        slots = [self._degradable(
                     lambda i=i: self._disp[i].enqueue(
                         "warm", {"backend": self.splade_backend}))
                 for i in range(self.n_shards)]
        return [None if s is None else
                self._degradable(lambda i=i, s=s: self._disp[i].wait(s))
                for i, s in enumerate(slots)]

    def _centroids(self):
        """Replicated centroid geometry, loaded once from shard 0's
        subtree (metadata-sized; byte-identical across shards)."""
        if self._centroids_cache is None:
            import pathlib as _pl
            self._centroids_cache = jnp.asarray(np.load(
                _pl.Path(self.shard_dirs[0]) / "colbert"
                / "centroids.npy"))
        return self._centroids_cache

    def _plaid_salt(self) -> str:
        sp = self.plaid_params
        return f"np{sp.nprobe}|cc{sp.candidate_cap}|nd{sp.ndocs}"

    # ------------------------------------------------------------------
    # live (mutable) index over process workers
    # ------------------------------------------------------------------
    # The delta segment and the tombstone set live at the coordinator
    # (delta docs are scored coordinator-side via the merge bodies' live
    # injection); workers only need their local tombstones for SPLADE
    # pre-top-k exclusion, replicated by the ``live_sync`` write RPC.
    _live_inline = False

    def enable_live(self):
        """Attach coordinator-side live state; geometry is loaded from
        shard 0's subtree (replicated, metadata-sized). Remote replica
        endpoints are unsupported — delta replication is local-only."""
        if self.live is not None:
            return self.live
        for rs in self._replica_sets:
            for r in rs.replicas:
                if r.endpoint is not None:
                    raise ValueError(
                        "live index over remote replica endpoints is "
                        "unsupported (mutation replication is "
                        "local-only)")
        from repro.index.builder import ColBERTIndex
        from repro.index.live import LiveIndexState
        from repro.index.splade_index import SpladeIndex
        d = pathlib.Path(self.shard_dirs[0])
        live = LiveIndexState(ColBERTIndex(d / "colbert", mode="mmap"),
                              SpladeIndex.load(d / "splade", mmap=True))
        live.base_n = self.n_docs
        self.live = live
        return live

    def _broadcast_live_sync(self, j: int):
        """Full-state tombstone sync to every live replica of shard
        ``j`` — direct synchronous calls, never hedged or retried on
        siblings (``MUTATION_OPS``). A replica that is down right now
        is skipped; it re-syncs on the next mutation's broadcast
        (eventual consistency — quiesce-point parity only requires the
        replicas serving traffic to be current)."""
        payload = {"tombstones": self.live.local_tombstones(
                       int(self.offsets[j]), int(self.offsets[j + 1])),
                   "generation": self.index_generation}
        for r in self._replica_sets[j].replicas:
            cli = r.client
            if cli is None or not cli.alive():
                continue
            cli.call("live_sync", payload,
                     timeout_ms=self.op_deadline_ms)

    def live_delete(self, gpid: int) -> bool:
        live = self._require_live()
        with self._live_mut:
            ok = live.delete(gpid)
            if not ok:
                return False
            self.bump_index_generation()
            gpid = int(gpid)
            if gpid < live.base_n:
                j = int(np.searchsorted(self.offsets, gpid,
                                        side="right") - 1)
                self._broadcast_live_sync(j)
        return True

    def compact_live(self):
        """Merge the delta prefix into the last shard (pid-preserving —
        see :meth:`ShardedRetriever.compact_live`): build the new
        generation's subtree off-gate, then under the write gate grow
        the boundary, rebase, repoint ``shard_dirs[-1]`` (so respawns
        load the compacted layout) and ``live_reload`` every replica."""
        live = self._require_live()
        with self._live_mut:
            n_take = live.snapshot_delta()
            if n_take == 0:
                return None
            from repro.index import live as live_mod
            from repro.index.builder import ColBERTIndex
            from repro.index.splade_index import SpladeIndex
            last_dir = pathlib.Path(self.shard_dirs[-1])
            gen = self.index_generation + 1
            tree = last_dir.with_name(f"{last_dir.name}.g{gen}")
            col_dir, spl_dir = tree / "colbert", tree / "splade"
            live_mod.compact_colbert_dir(
                ColBERTIndex(last_dir / "colbert", mode="mmap"),
                live, n_take, col_dir)
            live_mod.compact_splade_dir(
                SpladeIndex.load(last_dir / "splade", mmap=True),
                live, n_take, spl_dir)
            j = self.n_shards - 1
            with live.gate.write():
                self.shard_dirs[j] = str(tree)
                self.offsets[j + 1] += n_take   # plan closures see this
                self.n_docs = int(self.offsets[-1])
                live.rebase(n_take)
                self.bump_index_generation()
                payload = {
                    "colbert_dir": str(col_dir),
                    "splade_dir": str(spl_dir),
                    "tombstones": live.local_tombstones(
                        int(self.offsets[j]), int(self.offsets[j + 1])),
                    "generation": self.index_generation}
                for r in self._replica_sets[j].replicas:
                    cli = r.client
                    if cli is None or not cli.alive():
                        continue
                    cli.call("live_reload", payload,
                             timeout_ms=self.op_deadline_ms)
                with self._lock:
                    self._plans.clear()
        return {"compacted": n_take, "colbert_dir": str(col_dir),
                "splade_dir": str(spl_dir)}

    # ------------------------------------------------------------------
    # RPC stage plans
    # ------------------------------------------------------------------
    def _build_plan(self, method: str) -> StagePlan:
        """Compile the scatter-gather stage graph with per-shard work
        delegated to the worker processes. Coordinator-side stages are
        the shared merge/fuse bodies; per-shard RPC stages are
        DEVICE-kind (the worker pool is this plan's compute resource —
        socket waits release the GIL exactly like a device sync)."""
        p = self.params
        S = self.n_shards
        offs = self.offsets
        backend = self.splade_backend
        ndocs = min(self.plaid_params.ndocs,
                    self.plaid_params.candidate_cap)

        if method == "colbert":
            from repro.core.plaid import (
                pad_query_batch,
                stage1_centroid_probe_batch,
            )
            nprobe = self.plaid_params.nprobe

            def probe(cb):
                # ONE centroid probe for the whole group (replicated
                # geometry), synced to host here so every downstream
                # stage ships plain numpy
                q, q_valid = pad_query_batch(cb.q_embs)
                B, q, q_valid = _pad_batch_rows(q, q_valid)
                scores_c, cids = stage1_centroid_probe_batch(
                    q, q_valid, self._centroids(), nprobe)
                return cb.with_state(
                    B=B, q=np.asarray(q), q_valid=np.asarray(q_valid),
                    scores_c=np.asarray(scores_c),
                    cids=np.asarray(cids))

            def candidates_rpc(cb, i):
                st = cb.state
                r = self._degradable(lambda: self._disp[i].call(
                    "colbert_candidates",
                    {"scores_c": st["scores_c"], "cids": st["cids"],
                     "q_valid": st["q_valid"]}))
                if r is None:
                    return {"missing": True}
                return {"cand_np": r["cand"], "approx_np": r["approx"],
                        "n_real": r["n_real"]}

            def exact_rpc(cb, i):
                st = cb.state
                cols, sel = compact_owned(st["final_g"],
                                          offs[i], offs[i + 1])
                r = self._degradable(lambda: self._disp[i].call(
                    "colbert_exact",
                    {"q": st["q"], "q_valid": st["q_valid"],
                     "sel": sel}))
                if r is None:
                    return {"missing": True}
                return {"cols": cols, "exact_np": r["scores"]}

            stages = (
                Stage("plaid_probe", DEVICE, probe),
                Stage("shard_rpc:candidates", DEVICE, candidates_rpc,
                      fanout=S, pooled=True),
                Stage("merge_topk:approx", HOST,
                      lambda cb: merge_approx_state(cb, offs, ndocs,
                                                    live=self.live)),
                Stage("shard_rpc:exact", DEVICE, exact_rpc,
                      fanout=S, pooled=True),
                Stage("merge_topk", HOST,
                      lambda cb: fuse_colbert_state(cb, live=self.live)))
            return StagePlan(method=method, stages=stages,
                             access_stats=None, pool=self._pool)

        def splade_stage(cb):
            """Group stage 1: every shard's request goes onto its wire
            *before* any reply is read (pipelined sockets), so all S
            worker processes score their postings slices concurrently —
            the process analogue of dispatch-all-then-sync-all. Under
            concurrent micro-batches the dispatcher coalesces the
            stage-1 ops that land on a busy worker into one frame."""
            cached = self._stage1_group_lookup(cb)
            if cached is not None:
                return cb.with_state(stage1_cached=cached)
            payload = {"term_ids": list(cb.term_ids),
                       "term_weights": list(cb.term_weights),
                       "k": p.first_k, "backend": backend}
            slots = [self._degradable(
                         lambda i=i: self._disp[i].enqueue("splade",
                                                           payload))
                     for i in range(S)]
            outs = [None if s is None else
                    self._degradable(
                        lambda i=i, s=s: self._disp[i].wait(s))
                    for i, s in enumerate(slots)]
            return cb.evolve(shard_states=tuple(
                {"missing": True} if r is None else
                {"pids": np.where(r["pids"] >= 0,
                                  r["pids"] + offs[i], -1),
                 "scores": r["scores"]}
                for i, r in enumerate(outs)))

        def fuse_splade(cb):
            cached = cb.state.get("stage1_cached")
            if cached is not None:
                pids_b, s_scores = cached
                return cb.evolve(pids=pids_b[:, :cb.k],
                                 scores=s_scores[:, :cb.k])
            cb = fuse_splade_state(cb, p.first_k, live=self.live)
            self._stage1_group_store(cb)
            return cb

        def merge_stage1(cb):
            cached = cb.state.get("stage1_cached")
            if cached is not None:
                return stage1_state_from_rows(cb, *cached)
            cb = merge_stage1_state(cb, p.first_k, live=self.live)
            self._stage1_group_store(cb)
            return cb

        if method == "splade":
            stages = (Stage("splade_stage1", DEVICE, splade_stage),
                      Stage("merge_topk", HOST, fuse_splade))
            return StagePlan(method=method, stages=stages,
                             access_stats=None, pool=self._pool)

        # rerank / hybrid: merged SPLADE candidates → per-shard RPC
        # (compacted gather + MaxSim inside the worker) → global fuse.
        # The dispatch/wait split is what preserves the executor's
        # software pipelining: the batch parks at the wait stage while
        # its S workers gather+score, and the coordinator runs the next
        # batch's host stages.
        def score_dispatch(cb, i):
            st = cb.state
            cols, sel = compact_owned(st["gp"], offs[i], offs[i + 1])
            slot = self._degradable(lambda: self._disp[i].enqueue(
                "score_tokens",
                {"q": st["q"], "q_valid": st["q_valid"], "sel": sel}))
            if slot is None:
                return {"missing": True}
            return {"cols": cols, "_slot": slot}

        def score_wait(cb, i):
            s = dict(cb.shard_states[i])
            if s.get("missing"):
                return s
            slot = s.pop("_slot")
            r = self._degradable(lambda: self._disp[i].wait(slot))
            if r is None:
                return {"missing": True}
            s["c_dev"] = r["scores"][:cb.state["B"]]
            return s

        stages = (
            Stage("splade_stage1", DEVICE, splade_stage),
            Stage("merge_topk:stage1", HOST, merge_stage1),
            Stage("shard_rpc:score", DEVICE, score_dispatch, fanout=S,
                  opens_async=True),
            Stage("shard_rpc:wait", DEVICE, score_wait, fanout=S,
                  closes_async=True),
            Stage("fuse_topk", HOST,
                  lambda cb: fuse_scatter_rerank(cb, method, p.normalizer,
                                                 live=self.live)))
        return StagePlan(method=method, stages=stages,
                         access_stats=None, pool=self._pool)


def build_shard_group(shard_dirs, boundaries, *, workers: str = "thread",
                      mode: str = "mmap", plaid_params=None,
                      multistage_params=None, devices=None,
                      transport=None, arena_bytes=None, **kw):
    """Load a shard group behind either worker backend.

    ``workers="thread"`` → in-process :class:`ShardedRetriever`
    (:func:`build_sharded_retriever`); ``workers="process"`` → one OS
    process per shard behind a :class:`ProcessShardGroup`. Both present
    the same retriever interface and return identical results.
    ``transport`` (process workers only): ``"shm"`` zero-copy ring
    arenas / ``"socket"`` in-frame segments; None picks the platform
    default (:func:`repro.launch.mesh.default_shard_transport`)."""
    if workers == "process":
        return ProcessShardGroup(shard_dirs, boundaries, mode=mode,
                                 plaid_params=plaid_params,
                                 multistage_params=multistage_params,
                                 transport=transport,
                                 arena_bytes=arena_bytes,
                                 **kw)
    if workers != "thread":
        raise ValueError(f"shard workers {workers!r} not in "
                         f"('thread', 'process')")
    return build_sharded_retriever(shard_dirs, boundaries, mode=mode,
                                   plaid_params=plaid_params,
                                   multistage_params=multistage_params,
                                   devices=devices)
