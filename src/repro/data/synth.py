"""Synthetic retrieval corpora with planted relevance.

The paper's quality claims are *relationships* between systems
(Hybrid ≥ Rerank ≥ SPLADE; ColBERTv2 strong; α-sweep rises then falls).
To validate them without trained checkpoints we generate corpora from a
latent topic model in which the two retrievers see *complementary*
noisy views of relevance:

* **Semantic view (ColBERT)** — token embeddings cluster around a doc
  topic vector; query embeddings are noisy copies of the relevant doc's
  topic. MaxSim recovers relevance up to embedding noise.
* **Lexical view (SPLADE)** — docs draw terms from topic-specific
  Zipfian vocabularies; queries copy doc terms but with a synonym gap
  (some terms swapped within the topic's synonym groups) plus mild
  expansion. Impact scoring recovers relevance up to the lexical gap.

Because the noise sources are independent, interpolating the two scores
(the paper's Hybrid) beats either alone — the mechanism the paper
credits for Hybrid's wins, reproduced in a controlled setting.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SynthCfg:
    n_docs: int = 2000
    n_queries: int = 200
    vocab: int = 4096
    dim: int = 64
    n_topics: int = 64
    doc_maxlen: int = 32
    doc_minlen: int = 12
    query_maxlen: int = 8
    sparse_terms: int = 24        # nnz terms per doc sparse vector
    query_terms: int = 12         # nnz terms per query sparse vector
    doc_sig: float = 0.9          # doc-identity strength over its topic
    sem_noise: float = 1.5        # embedding-space query noise
    confuser: float = 0.45        # noise directed at a same-topic hard negative
    tok_noise: float = 0.45       # doc token scatter around doc identity
    lex_gap: float = 0.35         # synonym-substitution probability
    lex_drop: float = 0.20        # query terms replaced by random topic terms
    terms_per_topic: int = 96
    seed: int = 0


def _unit(x, axis=-1):
    n = np.linalg.norm(x, axis=axis, keepdims=True)
    return x / np.maximum(n, 1e-9)


def make_corpus(cfg: SynthCfg):
    rng = np.random.default_rng(cfg.seed)

    topics = _unit(rng.normal(size=(cfg.n_topics, cfg.dim)))
    # topic → term vocabulary (overlapping blocks + synonym pairing)
    topic_terms = np.stack([
        rng.choice(cfg.vocab, cfg.terms_per_topic, replace=False)
        for _ in range(cfg.n_topics)])
    # synonym of term t within a topic = the paired term one slot over
    syn_of = np.roll(topic_terms, 1, axis=1)

    # ---------------- documents ----------------
    doc_topic = rng.integers(0, cfg.n_topics, cfg.n_docs)
    doc_lens = rng.integers(cfg.doc_minlen, cfg.doc_maxlen + 1, cfg.n_docs)

    # each doc has a *doc-specific* identity vector near its topic — this
    # is what late interaction can resolve that lexical matching cannot.
    # Noise is added as unit directions so the mixing coefficients are
    # cosine-meaningful regardless of dim.
    doc_vec = _unit(topics[doc_topic] + cfg.doc_sig * _unit(
        rng.normal(size=(cfg.n_docs, cfg.dim))))
    tok = _unit(rng.normal(size=(cfg.n_docs, cfg.doc_maxlen, cfg.dim)))
    doc_embs = _unit(doc_vec[:, None, :] + cfg.tok_noise * tok)
    mask = np.arange(cfg.doc_maxlen)[None] < doc_lens[:, None]
    doc_embs = (doc_embs * mask[..., None]).astype(np.float32)

    # sparse vectors: Zipfian draw from the doc's topic terms
    ranks = np.arange(1, cfg.terms_per_topic + 1)
    zipf = (1.0 / ranks) / np.sum(1.0 / ranks)
    doc_term_ids = np.zeros((cfg.n_docs, cfg.sparse_terms), np.int32)
    doc_term_w = np.zeros((cfg.n_docs, cfg.sparse_terms), np.float32)
    for d in range(cfg.n_docs):
        slots = rng.choice(cfg.terms_per_topic, cfg.sparse_terms,
                           replace=False, p=zipf)
        doc_term_ids[d] = topic_terms[doc_topic[d], slots]
        doc_term_w[d] = 1.0 + rng.exponential(0.5, cfg.sparse_terms)

    # ---------------- queries ----------------
    q_rel = rng.integers(0, cfg.n_docs, cfg.n_queries)   # relevant doc/query
    # hard negatives: part of the query noise points at another doc of the
    # same topic, so semantic errors are *confusions*, not random misses
    topic_docs = {t: np.nonzero(doc_topic == t)[0] for t in range(cfg.n_topics)}
    conf = np.array([rng.choice(topic_docs[doc_topic[d]]) for d in q_rel])
    noise_dir = _unit((1 - cfg.confuser) * _unit(rng.normal(
        size=(cfg.n_queries, cfg.query_maxlen, cfg.dim)))
        + cfg.confuser * doc_vec[conf][:, None, :])
    q_embs = _unit(doc_vec[q_rel][:, None, :]            # doc-specific signal
                   + cfg.sem_noise * noise_dir).astype(np.float32)

    q_term_ids = np.zeros((cfg.n_queries, cfg.query_terms), np.int32)
    q_term_w = np.zeros((cfg.n_queries, cfg.query_terms), np.float32)
    for qi in range(cfg.n_queries):
        d = q_rel[qi]
        t = doc_topic[d]
        pick = rng.choice(cfg.sparse_terms, cfg.query_terms, replace=False)
        terms = doc_term_ids[d, pick].copy()
        w = doc_term_w[d, pick] * (0.5 + rng.random(cfg.query_terms))
        # lexical gap: swap to an in-topic synonym the doc may not contain
        swap = rng.random(cfg.query_terms) < cfg.lex_gap
        for j in np.nonzero(swap)[0]:
            slot = np.nonzero(topic_terms[t] == terms[j])[0]
            if len(slot):
                terms[j] = syn_of[t, slot[0]]
        # topical drift: some query terms are topic-typical, not doc-specific
        drop = rng.random(cfg.query_terms) < cfg.lex_drop
        for j in np.nonzero(drop)[0]:
            terms[j] = topic_terms[t, rng.integers(cfg.terms_per_topic)]
        q_term_ids[qi], q_term_w[qi] = terms, w

    qrels = [{int(p)} for p in q_rel]
    return {
        "doc_embs": doc_embs.astype(np.float32),
        "doc_lens": doc_lens.astype(np.int32),
        "doc_term_ids": doc_term_ids,
        "doc_term_weights": doc_term_w,
        "q_embs": q_embs,
        "q_term_ids": q_term_ids,
        "q_term_weights": q_term_w,
        "qrels": qrels,
        "cfg": cfg,
    }


def make_token_corpus(rng: np.random.Generator, n_docs: int, vocab: int,
                      doc_maxlen: int, doc_minlen: int = 8):
    """Plain integer token docs (for exercising the real encoders)."""
    lens = rng.integers(doc_minlen, doc_maxlen + 1, n_docs)
    toks = rng.integers(4, vocab, (n_docs, doc_maxlen)).astype(np.int32)
    toks *= (np.arange(doc_maxlen)[None] < lens[:, None])
    return toks, lens.astype(np.int32)
