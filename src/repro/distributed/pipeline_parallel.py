"""GPipe-style pipeline parallelism over a mesh axis.

The multi-pod meshes in this framework use the 'pod' axis as outer data
parallelism by default; this module provides the alternative — running
layer *stages* across an axis with microbatched execution and
``ppermute`` hand-offs — for models whose per-layer weights exceed a
pod's memory even fully sharded (the 1000+-node regime in DESIGN.md §6).

Schedule: classic GPipe fill-drain. With S stages and M microbatches
the loop runs M + S − 1 ticks; at tick t, stage s computes microbatch
t − s (when in range) and hands its activation to stage s+1. Bubble
fraction = (S−1)/(M+S−1); choose M ≥ 4·S to keep it under ~20 %.

``pipeline_apply`` is written for use inside ``shard_map`` where the
stage axis is a real mesh axis; every device executes every tick
(inactive ticks compute on garbage and are masked), which is exactly
how a static SPMD pipeline runs on hardware.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, params_local, xs, *,
                   axis: str, n_stages: int):
    """Run inside shard_map. params_local: this stage's params (leading
    stage axis of size 1 already sliced off by shard_map).
    xs: (M, mb, ...) microbatches — meaningful on stage 0, ignored
    elsewhere. Returns (M, mb, ...) outputs valid on the LAST stage and
    psum-broadcast so every stage holds them."""
    s_idx = jax.lax.axis_index(axis)
    M = xs.shape[0]
    S = n_stages
    zero = jnp.zeros_like(xs[0])

    def tick(t, carry):
        outputs, cur = carry
        # stage 0 injects microbatch t (while in fill range)
        mb_in = jax.lax.dynamic_index_in_dim(
            xs, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
        cur = jnp.where((s_idx == 0) & (t < M), mb_in, cur)
        y = stage_fn(params_local, cur)
        # last stage commits microbatch t − (S−1)
        out_t = t - (S - 1)
        commit = (s_idx == S - 1) & (out_t >= 0)
        outputs = jax.lax.dynamic_update_index_in_dim(
            outputs,
            jnp.where(commit, y,
                      jax.lax.dynamic_index_in_dim(
                          outputs, jnp.clip(out_t, 0, M - 1), 0,
                          keepdims=False)),
            jnp.clip(out_t, 0, M - 1), 0)
        # hand activations down the pipe
        y_next = jax.lax.ppermute(
            y, axis, [(i, i + 1) for i in range(S - 1)])
        return outputs, y_next

    outputs0 = jnp.zeros_like(xs)
    outputs, _ = jax.lax.fori_loop(0, M + S - 1, tick, (outputs0, zero))
    # broadcast the last stage's outputs to every stage
    mask = (s_idx == S - 1).astype(outputs.dtype)
    return jax.lax.psum(outputs * mask, axis)


def make_pipelined_fn(stage_fn: Callable, mesh, *, axis: str = "pipe",
                      n_stages: int):
    """jit-able pipelined apply: (params_stacked (S, ...), xs (M, mb, …))
    → (M, mb, …). Params are stage-sharded over ``axis``; inputs and
    outputs replicated (shard the mb axis over 'data' outside)."""
    fn = shard_map(
        partial(_pipeline_entry, stage_fn, axis, n_stages),
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False)
    return fn


def _pipeline_entry(stage_fn, axis, n_stages, params_stacked, xs):
    # shard_map hands each device a (1, ...) slice of the stacked params
    params_local = jax.tree_util.tree_map(lambda p: p[0], params_stacked)
    return pipeline_apply(stage_fn, params_local, xs, axis=axis,
                          n_stages=n_stages)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
