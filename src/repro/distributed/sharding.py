"""Path-based sharding rules.

Every param tree in the framework is sharded by matching each leaf's
tree path against an ordered rule table. A rule maps to a PartitionSpec
for the *trailing* dims of the leaf; leading dims (e.g. the stacked
layer axis under scan) are padded with ``None``.

Axis conventions (see launch/mesh.py):
  * ``data``  — batch / FSDP axis (16-way per pod)
  * ``model`` — TP / EP / vocab axis (16-way)
  * ``pod``   — outer data-parallel axis (multi-pod only)
"""

from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# A rule: (path regex, spec for trailing dims). First match wins.
# `F` marks the FSDP axis and `T` the tensor-parallel axis; they are
# substituted at build time so the same tables serve 1-pod and 2-pod
# meshes (and a hillclimb can remap them).
LM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                     ("F", "T")),      # (V, D)
    (r"lm_head$",                   ("F", "T")),      # (D, V)
    (r"mtp/proj$",                  ("F", "T")),
    (r"attn/(wq|wk|wv)$",           ("F", "T")),      # (D, Hh) col-parallel
    (r"attn/(bq|bk|bv)$",           ("T",)),
    (r"attn/wo$",                   ("T", "F")),      # (Hh, D) row-parallel
    (r"attn/wq_a$",                 ("F", "T")),
    (r"attn/wq_b$",                 ("F", "T")),
    (r"attn/wkv_a$",                ("F", "T")),
    (r"attn/wkv_b$",                ("F", "T")),
    (r"ffn/router$",                ("F", None)),
    (r"ffn/router_bias$",           (None,)),
    (r"ffn/(w_gate|w_up)$",         ("F", "T")),      # dense & shared FFN
    (r"ffn/w_down$",                ("T", "F")),
    (r"ffn/experts_w_(gate|up)$",   ("T", "F", None)),  # (E, D, F) EP on E
    (r"ffn/experts_w_down$",        ("T", None, "F")),  # (E, F, D)
    (r"(scale|bias)$",              (None,)),         # norms replicated
]

# RecSys: huge tables row-sharded on T (model) so lookups become
# collective gathers; the small interaction/MLP params are replicated
# (sub-MB — sharding them would only add collectives).
RECSYS_RULES: list[tuple[str, tuple]] = [
    (r"tables/.*$",                 ("T", None)),     # (vocab_rows, dim)
    (r"item_embed$",                ("T", None)),
    (r"lr_weight$",                 ("T", None)),
    (r"out_bias$",                  ("T",)),
    (r".*",                         None),            # everything else
]

# MACE GNN: small params — replicate everything; edges shard the work.
GNN_RULES: list[tuple[str, tuple]] = [
    (r".*",                         None),            # fully replicated
]

# Retrieval (paper system): index sharded over T on the document axis.
RETRIEVAL_RULES: list[tuple[str, tuple]] = [
    (r"centroids$",                 (None, None)),
    (r"(residuals|codes)$",         ("T", None)),
    (r".*",                         None),
]


def _spec_for_leaf(path: str, shape: tuple, rules, fsdp_axis, tp_axis) -> P:
    for pat, trailing in rules:
        if re.search(pat, path):
            if trailing is None:
                return P()
            sub = []
            for ax in trailing:
                if ax == "F":
                    sub.append(fsdp_axis)
                elif ax == "T":
                    sub.append(tp_axis)
                else:
                    sub.append(ax)
            pad = len(shape) - len(sub)
            if pad < 0:  # leaf has fewer dims than rule (e.g. unstacked bias)
                sub = sub[-len(shape):] if len(shape) else []
                pad = 0
            return P(*([None] * pad + sub))
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def make_param_specs(tree, rules, *, fsdp_axis="data", tp_axis="model"):
    """PartitionSpec pytree mirroring ``tree`` (works on SDS trees)."""
    def f(path, leaf):
        return _spec_for_leaf(_path_str(path), tuple(leaf.shape), rules,
                              fsdp_axis, tp_axis)
    return jax.tree_util.tree_map_with_path(f, tree)


def make_param_shardings(mesh: Mesh, tree, rules, *, fsdp_axis="data",
                         tp_axis="model"):
    specs = make_param_specs(tree, rules, fsdp_axis=fsdp_axis, tp_axis=tp_axis)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def batch_axes(mesh: Mesh) -> tuple:
    """The composite batch-sharding axes for this mesh ('pod' folded in)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_specs_lm(mesh: Mesh):
    """Input specs for LM train: tokens/labels (B, L)."""
    ba = batch_axes(mesh)
    return {"tokens": P(ba, None), "labels": P(ba, None)}


def cache_spec_gqa(mesh: Mesh):
    ba = batch_axes(mesh)
    return P(None, ba, None, "model", None)  # (layers, B, S, K, h)


def cache_spec_mla(mesh: Mesh):
    ba = batch_axes(mesh)
    return P(None, ba, None, None)  # (layers, B, S, r) — latent replicated on T


def make_cache_shardings(mesh: Mesh, cache_tree, *,
                         batch: Optional[int] = None):
    """Shardings for a decode cache pytree from init_cache/abstract_cache.

    Default: batch over the data axes, kv-heads over 'model' (GQA) or
    cache-seq over 'model' (MLA latent — no head axis worth splitting).
    When ``batch`` is smaller than the data-parallel width (long-context
    decode, B=1) the cache-sequence axis shards over the *whole* mesh so
    the multi-hundred-GB cache still spreads.
    """
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_ways = 1
    for ax in ba:
        data_ways *= sizes[ax]
    seq_mode = batch is not None and batch < data_ways
    all_axes = tuple(mesh.axis_names)

    def f(path, leaf):
        name = _path_str(path)
        if name.endswith("positions") and len(leaf.shape) == 2:
            if seq_mode:
                return NamedSharding(mesh, P(None, all_axes))
            return NamedSharding(mesh, P(ba, None))
        if re.search(r"/(k|v)$", name):
            if seq_mode:
                return NamedSharding(mesh, P(None, None, all_axes, None, None))
            if leaf.shape[3] % sizes["model"] == 0:   # kv heads divide TP
                return NamedSharding(mesh, P(None, ba, None, "model", None))
            return NamedSharding(mesh, P(None, ba, "model", None, None))
        if re.search(r"/(c_kv|k_rope)$", name):
            if seq_mode:
                return NamedSharding(mesh, P(None, None, all_axes, None))
            return NamedSharding(mesh, P(None, ba, "model", None))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(f, cache_tree)


def opt_state_shardings(mesh: Mesh, param_specs, opt_abstract):
    """Shardings for an AdamWState built over params with ``param_specs``.

    Moment payloads mirror the parameter layout; int8-quantised moments
    keep the parameter spec on the int8 payload, while the per-block
    scale drops the trailing axis (its block count rarely divides the
    TP width; scales are 1/128 of the payload, so replication on that
    axis is free)."""
    def _no_last(spec: P) -> P:
        if len(spec) == 0:
            return spec
        return P(*spec[:-1], None)

    def like(spec, sub):
        if isinstance(sub, dict):   # quantised moment {q, scale}
            return {"q": NamedSharding(mesh, spec),
                    "scale": NamedSharding(mesh, _no_last(spec))}
        return NamedSharding(mesh, spec)

    m = jax.tree_util.tree_map(like, param_specs, opt_abstract.m)
    v = jax.tree_util.tree_map(like, param_specs, opt_abstract.v)
    return type(opt_abstract)(count=NamedSharding(mesh, P()), m=m, v=v)


def attach(sds_tree, sharding_tree):
    """ShapeDtypeStructs with shardings attached — jit.lower() inputs."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        sds_tree, sharding_tree)


def sds(shape, dtype, mesh: Mesh, spec: P):
    """One ShapeDtypeStruct with a NamedSharding attached."""
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def replicated(mesh: Mesh, tree):
    return jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), tree)
