from repro.common.utils import (
    PRNGSeq,
    count_params,
    param_bytes,
    tree_shapes,
    cdiv,
    round_up,
)

__all__ = [
    "PRNGSeq",
    "count_params",
    "param_bytes",
    "tree_shapes",
    "cdiv",
    "round_up",
]
