"""Small shared utilities: PRNG sequencing, tree accounting, rounding."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class PRNGSeq:
    """An iterator of fresh PRNG keys split from a root seed.

    Usage::

        keys = PRNGSeq(0)
        w = init(next(keys), ...)
    """

    def __init__(self, seed_or_key):
        if isinstance(seed_or_key, int):
            self._key = jax.random.PRNGKey(seed_or_key)
        else:
            self._key = seed_or_key

    def __next__(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def __iter__(self):
        return self

    def take(self, n: int):
        self._key, *subs = jax.random.split(self._key, n + 1)
        return subs


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _leaf_size(x) -> int:
    if hasattr(x, "size"):
        return int(x.size)
    return 0


def _leaf_nbytes(x) -> int:
    if hasattr(x, "size") and hasattr(x, "dtype"):
        return int(x.size) * np.dtype(x.dtype).itemsize
    return 0


def count_params(tree) -> int:
    """Total element count across a pytree (works on ShapeDtypeStruct too)."""
    return sum(_leaf_size(x) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    """Total byte count across a pytree (works on ShapeDtypeStruct too)."""
    return sum(_leaf_nbytes(x) for x in jax.tree_util.tree_leaves(tree))


def tree_shapes(tree):
    """Map a pytree to a readable {path: (shape, dtype)} dict."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(p) for p in path)
        out[name] = (tuple(getattr(leaf, "shape", ())), str(getattr(leaf, "dtype", "?")))
    return out


def assert_no_nans(tree, where: str = ""):
    """Host-side NaN check over a pytree of concrete arrays."""
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        arr = np.asarray(leaf)
        if np.issubdtype(arr.dtype, np.floating) and not np.isfinite(arr).all():
            name = "/".join(str(p) for p in path)
            raise AssertionError(f"non-finite values at {name} {where}")


def shape_struct(shape, dtype=jnp.float32, sharding=None):
    """Convenience ShapeDtypeStruct builder."""
    if sharding is not None:
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
    return jax.ShapeDtypeStruct(shape, dtype)
