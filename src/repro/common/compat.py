"""Version-compatibility shims for the installed jax.

``jax.sharding.AxisType`` (explicit/auto mesh axis types) only exists on
newer jax releases; the pinned 0.4.x raises ``AttributeError`` on access.
Every mesh construction in the repo goes through :func:`make_mesh` so the
``axis_types`` kwarg is passed exactly when the runtime supports it.
"""

from __future__ import annotations

import jax


def has_axis_type() -> bool:
    return getattr(jax.sharding, "AxisType", None) is not None


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n_axes}`` when supported, else
    ``{}`` — splat into ``jax.make_mesh`` calls."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axis_names, *, devices=None):
    """``jax.make_mesh`` with Auto axis types on jax versions that have
    them and plain construction on those that don't."""
    kwargs = mesh_axis_types_kwargs(len(tuple(shape)))
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(shape, axis_names, **kwargs)
