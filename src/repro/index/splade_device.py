"""Device-resident SPLADE stage 1: padded postings + batched scoring.

The host CSR index (`SpladeIndex`) is the mmap/PISA tier. For the
device tier the postings are materialised **once** into the fixed-shape
``as_padded`` layout — (V, max_df) pids + uint8 impacts, ~5·V·max_df
bytes — and pinned as JAX arrays. Scoring a micro-batch is then a pure
device computation: gather the B×Qt query-term rows, run the batched
block kernel (or the segment-sum oracle), and take a fused per-query
top-k — a single dispatch for the whole batch.

Shape discipline: query-term counts are bucketed to powers of two (and
batch sizes are padded the same way by the caller) so the jitted
scorer compiles O(log) distinct shapes instead of one per (B, Qt).

Exactness: terms with df > max_df keep only their top-``max_df``
impacts (the documented memory/exactness tradeoff). With
``max_df=None`` the true maximum df is used and scoring is exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.utils import next_pow2 as _next_pow2
from repro.index.splade_index import SpladeIndex
from repro.kernels.splade_score.ops import splade_block_topk_batch


@functools.partial(jax.jit,
                   static_argnames=("n_docs", "k", "impl", "block_d",
                                    "chunk"))
def _score_topk(padded_pids, padded_imps, term_ids, term_weights, quantum,
                *, n_docs: int, k: int, impl: str, block_d: int,
                chunk: int):
    """term_ids (B, Qt) int32 (−1 pad); term_weights (B, Qt) f32 →
    (pids (B, k) int32, scores (B, k) f32). Gather + score + top-k in
    one jitted computation."""
    valid = (term_ids >= 0) & (term_weights > 0)
    safe_t = jnp.where(valid, term_ids, 0)
    post_pids = padded_pids[safe_t]                      # (B, Qt, max_df)
    post_imps = padded_imps[safe_t].astype(jnp.float32)  # de-quantise below
    w = jnp.where(valid, term_weights, 0.0) * quantum
    return splade_block_topk_batch(post_pids, post_imps, w, n_docs=n_docs,
                                   k=k, impl=impl, block_d=block_d,
                                   chunk=chunk)


class SpladeDeviceCache:
    """Owns the padded-postings device arrays for one `SpladeIndex` and
    serves batched stage-1 queries against them."""

    def __init__(self, index: SpladeIndex, max_df: int | None = None,
                 qt_min: int = 8, block_d: int = 2048, chunk: int = 512,
                 device=None):
        """``device`` pins the padded postings (and every query batch
        scored against them) to a specific jax.Device — a shard group
        maps shard i's cache to mesh device i so per-shard stage-1
        dispatches run on distinct hardware. ``None`` keeps the default
        device (single-device behaviour, unchanged)."""
        dfs = np.diff(index.term_offsets)
        true_max = int(dfs.max()) if len(dfs) else 1
        self.max_df = max(1, true_max if max_df is None
                          else min(int(max_df), true_max))
        self.truncated_terms = int((dfs > self.max_df).sum())
        pids, imps = index.as_padded(self.max_df)
        self.device = device
        put = (jnp.asarray if device is None
               else (lambda x: jax.device_put(x, device)))
        self.pids = put(pids)
        self.imps = put(imps)                  # uint8 on device
        self.quantum = float(index.quantum)
        self.n_docs = int(index.n_docs)
        self.qt_min = qt_min
        self.block_d = block_d
        self.chunk = chunk

    def nbytes(self) -> int:
        return int(self.pids.size * 4 + self.imps.size)

    # ------------------------------------------------------------------
    def pad_queries(self, term_ids, term_weights):
        """Stack ragged per-query term lists into pow2-bucketed (B, Qt)
        arrays (−1 / 0 padding) so compiled shapes are reused."""
        B = len(term_ids)
        vocab = self.pids.shape[0]
        qt = max((len(np.atleast_1d(t)) for t in term_ids), default=1)
        qt_pad = _next_pow2(max(qt, self.qt_min, 1))
        tids = np.full((B, qt_pad), -1, np.int32)
        w = np.zeros((B, qt_pad), np.float32)
        for i in range(B):
            t = np.atleast_1d(np.asarray(term_ids[i], np.int32))
            tw = np.atleast_1d(np.asarray(term_weights[i], np.float32))
            if (t >= vocab).any():
                # fail as loudly as the host CSR path would — a clamped
                # device gather would return plausible wrong scores
                raise IndexError(f"term id {int(t.max())} out of range "
                                 f"for vocab {vocab} (query {i})")
            tids[i, :len(t)] = t
            w[i, :len(tw)] = tw
        return tids, w

    def dispatch_topk(self, term_ids, term_weights, k: int,
                      impl: str = "auto"):
        """Issue the batched stage-1 dispatch and return it *lazy*:
        (device pids, device scores, k_eff, B, k) with no host sync —
        the dispatch queues on this cache's device and the caller syncs
        via :meth:`finalize_topk` when it needs host arrays. A shard
        group uses this to put every shard's stage-1 in flight (each on
        its own device) before paying any sync."""
        B = len(term_ids)
        tids, w = self.pad_queries(term_ids, term_weights)
        # pow2-pad the batch dim with zero-weight rows: nearby batch
        # sizes reuse one compiled scorer
        Bp = _next_pow2(max(B, 1))
        if Bp != B:
            tids = np.pad(tids, ((0, Bp - B), (0, 0)), constant_values=-1)
            w = np.pad(w, ((0, Bp - B), (0, 0)))
        k_eff = min(k, self.n_docs)
        if not k_eff:
            return None, None, 0, B, k
        put = (jnp.asarray if self.device is None
               else (lambda x: jax.device_put(x, self.device)))
        pids, scores = _score_topk(
            self.pids, self.imps, put(tids), put(w),
            jnp.float32(self.quantum), n_docs=self.n_docs,
            k=k_eff, impl=impl, block_d=self.block_d,
            chunk=self.chunk)
        return pids, scores, k_eff, B, k

    @staticmethod
    def finalize_topk(dispatched):
        """Sync a :meth:`dispatch_topk` result into the host
        (pids (B, k) int64, scores (B, k) f32), −1/0 padded like the
        host scorer."""
        pids, scores, k_eff, B, k = dispatched
        out_pids = np.full((B, k), -1, np.int64)
        out_scores = np.zeros((B, k), np.float32)
        if k_eff:
            out_pids[:, :k_eff] = np.asarray(pids)[:B]
            out_scores[:, :k_eff] = np.asarray(scores)[:B]
        return out_pids, out_scores

    def score_topk(self, term_ids, term_weights, k: int,
                   impl: str = "auto"):
        """Batched stage-1 over the device postings. term_ids /
        term_weights: sequences of (Qt_i,) arrays (ragged fine) →
        (pids (B, k) int64, scores (B, k) f32), −1/0 padded like the
        host scorer. One device dispatch per (bucketed) shape."""
        return self.finalize_topk(
            self.dispatch_topk(term_ids, term_weights, k, impl))
