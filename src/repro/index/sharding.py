"""Index sharding: split one SPLADE + ColBERT index into contiguous
document-range shards for scatter-gather serving.

A shard group partitions the corpus into ``n_shards`` contiguous pid
ranges. Every shard owns a complete, self-contained slice of all three
index structures:

* **SPLADE postings** — CSR postings filtered to the shard's pid range
  and remapped to shard-local ids. The *global* ``quantum`` is kept, so
  per-document impact scores are bit-identical to the unsharded index
  (re-quantising per shard would shift every score).
* **PLAID centroids/IVF** — the centroid set, bucket codec, and every
  other piece of geometry is **replicated** (it is metadata-sized);
  only the IVF postings are filtered + remapped. Identical geometry is
  what makes per-shard approximate/exact scores equal to the unsharded
  ones, so a global top-k merge reproduces the single-index ranking.
* **mmap PagedStore segment** — the token-range slice of codes.bin /
  residuals.bin for the shard's documents, as an independent file pair:
  per-shard gathers fault independent page streams.

``split_index_tree`` converts an on-disk single-shard index layout
(``<base>/colbert`` + ``<base>/splade``) in place: shards are written
under ``<base>/shards/<i>/{colbert,splade}`` next to the originals,
with a ``shards/meta.json`` recording the boundaries.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

from repro.core.store import PagedStore
from repro.index import ivf as ivf_mod
from repro.index.splade_index import SpladeIndex


def shard_boundaries(n_docs: int, n_shards: int) -> np.ndarray:
    """(n_shards+1,) int64 contiguous pid boundaries, balanced to within
    one document. Shard i owns pids [b[i], b[i+1])."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > n_docs:
        raise ValueError(f"n_shards={n_shards} exceeds n_docs={n_docs}")
    return np.linspace(0, n_docs, n_shards + 1).round().astype(np.int64)


def split_splade_index(sidx: SpladeIndex, boundaries: np.ndarray
                       ) -> list[SpladeIndex]:
    """Slice the CSR postings per shard (pids remapped to shard-local).

    The source ``quantum`` is carried over verbatim: shard-local scores
    must equal the global index's scores for the same document, or the
    merged top-k would not reproduce the single-index ranking."""
    # term id of every posting, recovered from the CSR offsets
    dfs = np.diff(sidx.term_offsets)
    terms = np.repeat(np.arange(sidx.vocab, dtype=np.int64), dfs)
    pids = np.asarray(sidx.pids)
    out = []
    for lo, hi in zip(boundaries[:-1], boundaries[1:]):
        keep = (pids >= lo) & (pids < hi)
        kept_terms = terms[keep]
        counts = np.bincount(kept_terms, minlength=sidx.vocab)
        offsets = np.zeros(sidx.vocab + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        out.append(SpladeIndex(
            term_offsets=offsets,
            pids=(pids[keep] - lo).astype(np.int32),
            impacts=np.asarray(sidx.impacts)[keep],
            quantum=sidx.quantum,          # global scale — see docstring
            n_docs=int(hi - lo), vocab=sidx.vocab))
    return out


def split_colbert_index(src_dir, out_dirs, boundaries: np.ndarray):
    """Write per-shard ColBERT index directories from a single index.

    ``src_dir``: an index built by ``build_colbert_index``;
    ``out_dirs``: one target directory per shard. The token pool is
    sliced by document range through a memmap (the source residuals are
    never fully materialised), geometry files are replicated, and the
    IVF is filtered + remapped per shard."""
    src = pathlib.Path(src_dir)
    meta = json.loads((src / "meta.json").read_text())
    n_tokens, packed_dim = meta["n_tokens"], meta["packed_dim"]
    doc_offsets = np.load(src / "doc_offsets.npy")
    doclens = np.load(src / "doclens.npy")
    residuals = np.memmap(src / "residuals.bin", np.uint8, "r",
                          shape=(n_tokens, packed_dim))
    codes = np.memmap(src / "codes.bin", np.int32, "r", shape=(n_tokens,))
    ivf_pids = np.fromfile(src / "ivf_pids.bin", np.int32)
    ivf_offsets = np.load(src / "ivf_offsets.npy")
    n_centroids = meta["n_centroids"]
    ivf_cids = np.repeat(np.arange(n_centroids, dtype=np.int64),
                         np.diff(ivf_offsets))

    if len(out_dirs) != len(boundaries) - 1:
        raise ValueError("one output dir per shard required")
    for (lo, hi), out_dir in zip(zip(boundaries[:-1], boundaries[1:]),
                                 out_dirs):
        out = pathlib.Path(out_dir)
        t_lo, t_hi = int(doc_offsets[lo]), int(doc_offsets[hi])
        PagedStore.write(out, np.asarray(codes[t_lo:t_hi]),
                         np.asarray(residuals[t_lo:t_hi]),
                         dim=meta["dim"], nbits=meta["nbits"])
        # geometry is replicated: identical centroids/buckets give the
        # shard bit-identical per-document scores
        for f in ("centroids.npy", "bucket_cutoffs.npy",
                  "bucket_weights.npy"):
            np.save(out / f, np.load(src / f))
        np.save(out / "doclens.npy", doclens[lo:hi])
        np.save(out / "doc_offsets.npy", doc_offsets[lo:hi + 1] - t_lo)
        keep = (ivf_pids >= lo) & (ivf_pids < hi)
        iv = _csr_from_pairs(ivf_cids[keep], ivf_pids[keep] - lo,
                             n_centroids)
        iv.pids.tofile(out / "ivf_pids.bin")
        np.save(out / "ivf_offsets.npy", iv.offsets)
        shard_meta = json.loads((out / "meta.json").read_text())
        shard_meta.update({"n_docs": int(hi - lo),
                           "doc_maxlen": meta["doc_maxlen"],
                           "n_centroids": n_centroids})
        (out / "meta.json").write_text(json.dumps(shard_meta))
    return list(out_dirs)


def _csr_from_pairs(cids, pids, n_centroids: int) -> ivf_mod.IVF:
    """CSR IVF from already-sorted-by-centroid (cid, pid) pairs. The
    source IVF is centroid-major, so a filtered slice stays sorted."""
    counts = np.bincount(cids, minlength=n_centroids)
    offsets = np.zeros(n_centroids + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return ivf_mod.IVF(pids=pids.astype(np.int32), offsets=offsets,
                       n_centroids=n_centroids)


def split_index_tree(base_dir, n_shards: int, group_dir=None):
    """Convert a serve-layout index (``<base>/{colbert,splade}``) into a
    shard group under ``<base>/shards/`` (or ``group_dir``). Idempotent
    per shard count: an existing group with the same ``n_shards`` is
    reused. Returns the shard-group directory."""
    base = pathlib.Path(base_dir)
    group = pathlib.Path(group_dir) if group_dir else base / "shards"
    meta_path = group / "meta.json"
    if meta_path.exists():
        meta = json.loads(meta_path.read_text())
        if meta["n_shards"] == n_shards:
            return group
    col_meta = json.loads((base / "colbert" / "meta.json").read_text())
    bounds = shard_boundaries(col_meta["n_docs"], n_shards)
    split_colbert_index(base / "colbert",
                        [group / str(i) / "colbert"
                         for i in range(n_shards)], bounds)
    sidx = SpladeIndex.load(base / "splade")
    for i, shard in enumerate(split_splade_index(sidx, bounds)):
        shard.save(group / str(i) / "splade")
    meta_path.write_text(json.dumps(
        {"n_shards": n_shards, "boundaries": bounds.tolist()}))
    return group


def load_group(group_dir):
    """Read a shard group's layout back from its ``meta.json``.

    Returns ``(shard_dirs, boundaries)`` — the inputs every group
    backend (in-process ``build_sharded_retriever``, process-worker
    ``ProcessShardGroup``, or a standalone ``repro.serving.worker``
    deployment script) needs to attach to a group written by
    :func:`split_index_tree`."""
    group = pathlib.Path(group_dir)
    meta = json.loads((group / "meta.json").read_text())
    dirs = [group / str(i) for i in range(meta["n_shards"])]
    return dirs, np.asarray(meta["boundaries"], np.int64)
