"""Batched k-means (Lloyd) in JAX, for ColBERTv2 centroid training.

Centroids live on the unit sphere (ColBERT embeddings are L2-normalised)
so assignment uses the max-inner-product == min-L2 equivalence.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("chunk",))
def assign(points, centroids, chunk: int = 8192):
    """points: (N, d); centroids: (K, d) → (ids (N,), sims (N,))."""
    N = points.shape[0]
    pad = (-N) % chunk
    pts = jnp.pad(points, ((0, pad), (0, 0)))
    pts = pts.reshape(-1, chunk, points.shape[1])

    def body(_, p):
        s = jnp.einsum("nd,kd->nk", p, centroids, preferred_element_type=jnp.float32)
        return None, (jnp.argmax(s, axis=-1).astype(jnp.int32), jnp.max(s, axis=-1))

    _, (ids, sims) = jax.lax.scan(body, None, pts)
    return ids.reshape(-1)[:N], sims.reshape(-1)[:N]


@functools.partial(jax.jit, donate_argnums=(1,))
def _update(points, centroids, ids):
    K, d = centroids.shape
    sums = jax.ops.segment_sum(points, ids, num_segments=K)
    counts = jax.ops.segment_sum(jnp.ones((points.shape[0],), jnp.float32),
                                 ids, num_segments=K)
    new = sums / jnp.maximum(counts[:, None], 1.0)
    # keep empty clusters where they were
    new = jnp.where(counts[:, None] > 0, new, centroids)
    norm = jnp.linalg.norm(new, axis=-1, keepdims=True)
    return new / jnp.maximum(norm, 1e-9), counts


def train_kmeans(key, points, n_centroids: int, n_iters: int = 10):
    """points: (N, d) float32 (unit-norm). Returns (K, d) unit centroids."""
    N = points.shape[0]
    idx = jax.random.choice(key, N, (n_centroids,), replace=N < n_centroids)
    centroids = points[idx]
    centroids = centroids / jnp.maximum(
        jnp.linalg.norm(centroids, axis=-1, keepdims=True), 1e-9)
    for _ in range(n_iters):
        ids, _ = assign(points, centroids)
        centroids, _ = _update(points, centroids, ids)
    return centroids


def pick_n_centroids(n_tokens: int) -> int:
    """ColBERTv2 heuristic: ~16·sqrt(120·N) rounded to a power of two."""
    target = 16 * np.sqrt(n_tokens)
    return int(2 ** int(np.clip(np.round(np.log2(max(target, 2))), 2, 18)))
