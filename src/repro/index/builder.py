"""End-to-end ColBERTv2 index construction.

embeddings (n_docs, doc_maxlen, dim) + lengths
    → k-means centroids → residual codec → packed codes/residuals
    → IVF → on-disk index directory (PagedStore format + metadata).
"""

from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.store import PagedStore
from repro.index import ivf as ivf_mod
from repro.index import kmeans, residual


def build_colbert_index(out_dir, doc_embs: np.ndarray, doc_lens: np.ndarray,
                        *, nbits: int = 4, n_centroids: int | None = None,
                        kmeans_iters: int = 8, sample_cap: int = 65536,
                        seed: int = 0, centroids: np.ndarray | None = None,
                        bucket_cutoffs: np.ndarray | None = None,
                        bucket_weights: np.ndarray | None = None):
    """doc_embs: (n_docs, doc_maxlen, dim) unit-norm; doc_lens: (n_docs,).

    Passing ``centroids`` + ``bucket_cutoffs`` + ``bucket_weights`` pins
    the geometry: k-means training and codec fitting are skipped and the
    corpus is encoded against the given codec. The live-index rebuild
    oracle uses this so a from-scratch rebuild of a mutated corpus is
    bitwise comparable to serving the base index + delta segment (both
    sides then quantise residuals identically)."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    n_docs, doc_maxlen, dim = doc_embs.shape

    # flatten valid tokens
    valid = np.arange(doc_maxlen)[None, :] < doc_lens[:, None]
    flat = doc_embs[valid]                                   # (n_tokens, dim)
    token_pids = np.repeat(np.arange(n_docs), doc_lens)
    n_tokens = flat.shape[0]

    if centroids is not None:
        if bucket_cutoffs is None or bucket_weights is None:
            raise ValueError("pinned geometry needs centroids, "
                             "bucket_cutoffs and bucket_weights together")
        centroids = np.asarray(centroids, np.float32)
        n_centroids = int(centroids.shape[0])
        codec = residual.ResidualCodec(
            centroids=jnp.asarray(centroids),
            bucket_cutoffs=jnp.asarray(bucket_cutoffs, jnp.float32),
            bucket_weights=jnp.asarray(bucket_weights, jnp.float32),
            nbits=nbits)
        cids = np.asarray(kmeans.assign(jnp.asarray(flat),
                                        jnp.asarray(centroids))[0])
    else:
        if n_centroids is None:
            n_centroids = max(16, min(kmeans.pick_n_centroids(n_tokens),
                                      n_tokens // 4))

        rng = np.random.default_rng(seed)
        sample = flat[rng.choice(n_tokens, min(sample_cap, n_tokens),
                                 replace=False)]
        centroids = kmeans.train_kmeans(jax.random.PRNGKey(seed),
                                        jnp.asarray(sample), n_centroids,
                                        kmeans_iters)
        centroids = np.asarray(centroids, np.float32)

        cids, _ = kmeans.assign(jnp.asarray(flat), jnp.asarray(centroids))
        cids = np.asarray(cids)

        codec = residual.fit_codec(centroids, sample,
                                   np.asarray(kmeans.assign(
                                       jnp.asarray(sample),
                                       jnp.asarray(centroids))[0]), nbits)
    packed = np.asarray(residual.encode_residuals(
        jnp.asarray(flat), jnp.asarray(cids), codec.centroids,
        codec.bucket_cutoffs, nbits))

    # persist
    PagedStore.write(out, cids, packed, dim=dim, nbits=nbits)
    np.save(out / "centroids.npy", centroids)
    np.save(out / "bucket_cutoffs.npy", np.asarray(codec.bucket_cutoffs))
    np.save(out / "bucket_weights.npy", np.asarray(codec.bucket_weights))
    np.save(out / "doclens.npy", doc_lens.astype(np.int32))
    offsets = np.zeros(n_docs + 1, np.int64)
    np.cumsum(doc_lens, out=offsets[1:])
    np.save(out / "doc_offsets.npy", offsets)

    iv = ivf_mod.build_ivf(cids, token_pids, n_centroids)
    iv.pids.tofile(out / "ivf_pids.bin")
    np.save(out / "ivf_offsets.npy", iv.offsets)

    meta = json.loads((out / "meta.json").read_text())
    meta.update({"n_docs": int(n_docs), "doc_maxlen": int(doc_maxlen),
                 "n_centroids": int(n_centroids)})
    (out / "meta.json").write_text(json.dumps(meta))
    return out


class ColBERTIndex:
    """Loaded index handle. ``mode`` picks the paper's mmap tier or the
    full-RAM baseline for the code/residual pool (everything else —
    centroids, buckets, doclens, IVF — is metadata and stays in RAM in
    both modes, exactly as in the paper)."""

    def __init__(self, path, mode: str = "mmap"):
        self.path = pathlib.Path(path)
        meta = json.loads((self.path / "meta.json").read_text())
        self.meta = meta
        self.n_docs = meta["n_docs"]
        self.doc_maxlen = meta["doc_maxlen"]
        self.dim = meta["dim"]
        self.nbits = meta["nbits"]
        self.n_centroids = meta["n_centroids"]

        self.centroids = np.load(self.path / "centroids.npy")
        self.bucket_cutoffs = np.load(self.path / "bucket_cutoffs.npy")
        self.bucket_weights = np.load(self.path / "bucket_weights.npy")
        self.doclens = np.load(self.path / "doclens.npy")
        self.doc_offsets = np.load(self.path / "doc_offsets.npy")
        ivf_pids = np.fromfile(self.path / "ivf_pids.bin", np.int32)
        ivf_offsets = np.load(self.path / "ivf_offsets.npy")
        self.ivf = ivf_mod.IVF(ivf_pids, ivf_offsets, self.n_centroids)
        self.store = PagedStore(self.path, mode=mode)

    def codec(self) -> residual.ResidualCodec:
        return residual.ResidualCodec(
            centroids=jnp.asarray(self.centroids),
            bucket_cutoffs=jnp.asarray(self.bucket_cutoffs),
            bucket_weights=jnp.asarray(self.bucket_weights),
            nbits=self.nbits)

    def gather_doc_tokens(self, pids: np.ndarray):
        """→ (cids (C, Ld), packed (C, Ld, pd), valid (C, Ld)) for pids
        (host path; goes through the PagedStore and is page-accounted)."""
        pids = np.asarray(pids)
        safe = np.clip(pids, 0, self.n_docs - 1)
        starts = self.doc_offsets[safe]
        cds, res = self.store.gather_ranges(starts, self.doc_maxlen)
        valid = self._doc_valid(pids, safe)
        return cds, res, valid

    def gather_doc_codes(self, pids: np.ndarray):
        """→ (cids (C, Ld), valid (C, Ld)): centroid ids only, for the
        codes-only approximate stage. Touches zero residual pages."""
        pids = np.asarray(pids)
        safe = np.clip(pids, 0, self.n_docs - 1)
        starts = self.doc_offsets[safe]
        cds = self.store.gather_codes_ranges(starts, self.doc_maxlen)
        return cds, self._doc_valid(pids, safe)

    def _doc_valid(self, pids, safe):
        valid = (np.arange(self.doc_maxlen)[None, :] < self.doclens[safe][:, None])
        valid &= (pids >= 0)[:, None]
        return valid
