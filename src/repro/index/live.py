"""Live (mutable) index: append-mode delta segment + tombstones + compaction.

The frozen serve layout (PagedStore codes/residuals, IVF, SPLADE CSR)
never changes shape under traffic; mutability is layered beside it:

* **Upserts** residual-encode the new document against the *existing*
  centroids/codec (`kmeans.assign` + `encode_residuals` are per-row
  deterministic, so delta codes are bitwise-identical to what a
  from-scratch rebuild would assign the same embeddings) and append it
  to an in-RAM delta segment: per-doc centroid ids, packed residuals,
  SPLADE postings. Delta docs get append-only global pids
  ``base_n + j`` — stable across compactions, because a compaction
  promotes exactly the first ``n`` delta docs into the base in order.
* **Deletes** record the global pid in a tombstone set. Tombstoned
  docs stay physically present (base *and* compacted layouts) and are
  filtered at the merge stages (`merge_topk` / SPLADE top-k), which is
  what keeps pids stable and deletes O(1).
* **Compaction** merges the delta prefix into a *new* index directory
  (``<index>.g<gen>``) off-line, then atomically swaps the serve
  handles under a writer gate and bumps the index generation so the
  PR-9 exact/stage-1 caches invalidate.

Correctness bar (enforced by tests/test_live_index.py and the churn
soak): an interleaved upsert/delete/query trace returns bitwise-
identical top-k to a from-scratch rebuild of the surviving corpus at
every quiesce point, under the monotone pid map (surviving global pids,
ascending) ↔ (0..n_survivors-1).
"""

from __future__ import annotations

import json
import pathlib
import threading
from contextlib import contextmanager

import jax.numpy as jnp
import numpy as np

from repro.core.store import PagedStore
from repro.index import ivf as ivf_mod
from repro.index import kmeans, residual
from repro.kernels.decompress_maxsim.ops import decompress_maxsim_scores_batch


class RWGate:
    """Readers/writer gate with writer preference and re-entrant reads.

    A *first-entry* reader blocks while a writer holds **or waits for**
    the gate, so the compaction swap cannot starve under a saturating
    read load (new queries queue behind the waiting writer; in-flight
    ones drain). A thread already inside ``read()`` re-enters without
    touching the queue — the mixed-batch path recurses into
    ``search_batch_ctx`` — so writer preference can never deadlock a
    reader against itself (the depth is tracked per-thread).
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._readers = 0
        self._writer = False
        self._writers_waiting = 0
        self._local = threading.local()

    @contextmanager
    def read(self):
        depth = getattr(self._local, "depth", 0)
        if depth:                      # nested read: already admitted
            self._local.depth = depth + 1
            try:
                yield
            finally:
                self._local.depth -= 1
            return
        with self._cond:
            while self._writer or self._writers_waiting:
                self._cond.wait()
            self._readers += 1
            self._local.depth = 1
        try:
            yield
        finally:
            with self._cond:
                self._readers -= 1
                self._local.depth = 0
                if not self._readers:
                    self._cond.notify_all()

    @contextmanager
    def write(self):
        with self._cond:
            self._writers_waiting += 1
            try:
                while self._writer or self._readers:
                    self._cond.wait()
                self._writer = True
            finally:
                self._writers_waiting -= 1
        try:
            yield
        finally:
            with self._cond:
                self._writer = False
                self._cond.notify_all()


class LiveView:
    """Shard-local view of the live state: the tombstones owned by one
    shard (local pids) plus counters — what a process worker needs to
    filter its own SPLADE stage. Delta docs never reach shard workers;
    they are scored at the coordinator."""

    gate = None

    def __init__(self, tombstones=None, generation: int = 0, counters=None):
        self.tombstones = np.sort(np.asarray(
            [] if tombstones is None else tombstones, np.int64).ravel())
        self.generation = int(generation)
        self.counters = dict(counters or {})

    def update(self, tombstones, generation=None, counters=None):
        """Replace the view wholesale (idempotent full-state sync)."""
        self.tombstones = np.sort(np.asarray(
            [] if tombstones is None else tombstones, np.int64).ravel())
        if generation is not None:
            self.generation = int(generation)
        if counters is not None:
            self.counters = dict(counters)

    @property
    def dirty(self) -> bool:
        return self.tombstones.size > 0

    @property
    def base_exclude(self) -> np.ndarray:
        return self.tombstones

    def stats(self) -> dict:
        out = {"tombstones": int(self.tombstones.size),
               "delta_docs": 0, "generation": self.generation}
        out.update(self.counters)
        return out


class LiveIndexState:
    """Owner-side mutable state: the delta segment, the tombstone set,
    the compaction gate, and the delta scoring primitives the serve
    paths compose (all bitwise-matched to their frozen counterparts)."""

    def __init__(self, index, splade):
        self.base_n = int(index.n_docs)
        self.doc_maxlen = int(index.doc_maxlen)
        self.dim = int(index.dim)
        self.nbits = int(index.nbits)
        self.packed_dim = int(index.store.packed_dim)
        self.n_centroids = int(index.n_centroids)
        self.quantum = float(splade.quantum)
        self.vocab = int(splade.vocab)
        self._centroids_j = jnp.asarray(index.centroids)
        self._cutoffs_j = jnp.asarray(index.bucket_cutoffs)
        self._bweights_j = jnp.asarray(index.bucket_weights)

        # append-only delta segment (per-doc arrays, list index = local pid)
        self._cids: list[np.ndarray] = []
        self._packed: list[np.ndarray] = []
        self._doclens: list[int] = []
        self._term_ids: list[np.ndarray] = []
        self._term_weights: list[np.ndarray] = []

        self._tomb: set[int] = set()
        self._tomb_arr = np.zeros(0, np.int64)
        self._tomb_dirty = False

        self.gate = RWGate()
        self._lock = threading.Lock()
        self.counters = {"upserts": 0, "deletes": 0, "compactions": 0,
                         "docs_compacted": 0}

        # lazy caches keyed on (base_n, n_delta)
        self._splade_cache = (None, None)
        self._ivf_cache = (None, None)

    # -- mutation ----------------------------------------------------------
    def encode_doc(self, doc_emb, doc_len=None):
        """Residual-encode one document against the frozen geometry.
        Returns (cids (L,) int32, packed (L, pd) uint8, L)."""
        emb = np.asarray(doc_emb, np.float32)
        if emb.ndim != 2 or emb.shape[1] != self.dim:
            raise ValueError(f"doc_emb must be (L, {self.dim}), got {emb.shape}")
        L = int(emb.shape[0] if doc_len is None else doc_len)
        if not (0 < L <= self.doc_maxlen):
            raise ValueError(f"doc_len {L} outside (0, {self.doc_maxlen}]")
        emb = emb[:L]
        cids, _ = kmeans.assign(jnp.asarray(emb), self._centroids_j)
        cids = np.asarray(cids, np.int32)
        packed = np.asarray(residual.encode_residuals(
            jnp.asarray(emb), jnp.asarray(cids), self._centroids_j,
            self._cutoffs_j, self.nbits), np.uint8)
        return cids, packed, L

    def upsert(self, doc_emb, term_ids, term_weights, doc_len=None) -> int:
        """Append one document to the delta segment → its global pid."""
        cids, packed, L = self.encode_doc(doc_emb, doc_len)
        t = np.asarray(term_ids, np.int32).ravel()
        w = np.asarray(term_weights, np.float32).ravel()
        with self._lock:
            j = len(self._doclens)
            self._cids.append(cids)
            self._packed.append(packed)
            self._doclens.append(L)
            self._term_ids.append(t)
            self._term_weights.append(w)
            self.counters["upserts"] += 1
            return self.base_n + j

    def delete(self, gpid: int) -> bool:
        """Tombstone a global pid. False if unknown or already deleted."""
        gpid = int(gpid)
        with self._lock:
            if gpid < 0 or gpid >= self.base_n + len(self._doclens):
                return False
            if gpid in self._tomb:
                return False
            self._tomb.add(gpid)
            self._tomb_dirty = True
            self.counters["deletes"] += 1
            return True

    # -- introspection -----------------------------------------------------
    @property
    def n_delta(self) -> int:
        return len(self._doclens)

    @property
    def dirty(self) -> bool:
        return bool(self._doclens) or bool(self._tomb)

    def tombstone_array(self) -> np.ndarray:
        """Sorted int64 snapshot of all tombstoned global pids."""
        with self._lock:
            if self._tomb_dirty:
                self._tomb_arr = np.array(sorted(self._tomb), np.int64)
                self._tomb_dirty = False
            return self._tomb_arr

    @property
    def base_exclude(self) -> np.ndarray:
        """Tombstoned *base* pids (for SPLADE score exclusion)."""
        t = self.tombstone_array()
        return t[t < self.base_n]

    def local_tombstones(self, lo: int, hi: int) -> np.ndarray:
        """Tombstoned pids within [lo, hi), shifted to shard-local."""
        t = self.tombstone_array()
        return t[(t >= lo) & (t < hi)] - lo

    def is_tombstoned(self, gpids) -> np.ndarray:
        """Vectorised tombstone membership for a global pid array."""
        return np.isin(np.asarray(gpids), self.tombstone_array())

    def stats(self) -> dict:
        with self._lock:
            out = dict(self.counters)
            out["delta_docs"] = len(self._doclens)
            out["delta_tokens"] = int(sum(self._doclens))
            out["tombstones"] = len(self._tomb)
        return out

    # -- SPLADE delta ------------------------------------------------------
    def _delta_splade(self, n: int):
        key, idx = self._splade_cache
        if key == (self.base_n, n):
            return idx
        from repro.index.splade_index import build_splade_index
        T = max(int(t.size) for t in self._term_ids[:n]) if n else 1
        ids = np.full((n, max(T, 1)), -1, np.int32)
        ws = np.zeros((n, max(T, 1)), np.float32)
        for j in range(n):
            t = self._term_ids[j]
            ids[j, :t.size] = t
            ws[j, :t.size] = self._term_weights[j]
        # the base quantum is pinned so delta impacts are bitwise what a
        # full rebuild (same quantum) would produce for these docs
        idx = build_splade_index(ids, ws, self.vocab, n, quantum=self.quantum)
        self._splade_cache = ((self.base_n, n), idx)
        return idx

    def splade_delta_topk(self, term_ids, term_weights, k: int):
        """Delta-only SPLADE top-k → ((B, k) global pids, (B, k) scores),
        padded (-1, 0.0); tombstoned delta docs excluded pre-top-k."""
        n = self.n_delta
        B = len(term_ids)
        if n == 0:
            return (np.full((B, k), -1, np.int64),
                    np.zeros((B, k), np.float32))
        t = self.tombstone_array()
        excl = t[t >= self.base_n] - self.base_n
        excl = excl[excl < n]
        pids_l, scores = self._delta_splade(n).score_batch_host(
            term_ids, term_weights, k, exclude=excl)
        pids = np.where(pids_l >= 0, pids_l.astype(np.int64) + self.base_n,
                        np.int64(-1))
        return pids, scores

    # -- PLAID delta -------------------------------------------------------
    def _delta_ivf(self, n: int) -> dict:
        key, d = self._ivf_cache
        if key == (self.base_n, n):
            return d
        d = {}
        for j in range(n):
            for c in np.unique(self._cids[j]).tolist():
                d.setdefault(int(c), []).append(j)
        d = {c: np.asarray(js, np.int64) for c, js in d.items()}
        self._ivf_cache = ((self.base_n, n), d)
        return d

    def delta_candidates(self, cids_np) -> list:
        """cids_np (B, Lq, nprobe) probed centroid ids → per-query sorted
        unique *global* delta candidate pids (tombstoned excluded)."""
        n = self.n_delta
        cids_np = np.asarray(cids_np)
        B = cids_np.shape[0]
        if n == 0:
            return [np.zeros(0, np.int64) for _ in range(B)]
        iv = self._delta_ivf(n)
        t = self.tombstone_array()
        excl = set((t[t >= self.base_n] - self.base_n).tolist())
        out = []
        for b in range(B):
            probed = np.unique(cids_np[b]).tolist()
            locs = [iv[c] for c in probed if c in iv]
            if not locs:
                out.append(np.zeros(0, np.int64))
                continue
            uniq = np.unique(np.concatenate(locs))
            if excl:
                uniq = uniq[~np.isin(uniq, np.array(sorted(excl), np.int64))]
            out.append(uniq + self.base_n)
        return out

    def _gather_delta(self, pids_mat, with_packed: bool):
        """(B, C) global delta pids (-1 pad) → (codes (B, C, Ld),
        packed (B, C, Ld, pd) | None, valid (B, C, Ld)) — the delta
        twin of ``PLAIDSearcher._dedup_gather``."""
        pids_mat = np.asarray(pids_mat)
        mask = pids_mat >= 0
        local = np.where(mask, pids_mat - self.base_n, 0).astype(np.int64)
        uniq = np.unique(local[mask]) if mask.any() else np.zeros(1, np.int64)
        Ld = self.doc_maxlen
        U = len(uniq)
        codes_u = np.zeros((U, Ld), np.int32)
        valid_u = np.zeros((U, Ld), bool)
        packed_u = (np.zeros((U, Ld, self.packed_dim), np.uint8)
                    if with_packed else None)
        for i, j in enumerate(uniq.tolist()):
            if 0 <= j < len(self._doclens):
                L = self._doclens[j]
                codes_u[i, :L] = self._cids[j]
                valid_u[i, :L] = True
                if with_packed:
                    packed_u[i, :L] = self._packed[j]
        pos = np.minimum(np.searchsorted(uniq, local), U - 1)
        codes = codes_u[pos]
        valid = valid_u[pos] & mask[..., None]
        packed = packed_u[pos] if with_packed else None
        return codes, packed, valid

    def approx_scores(self, scores_c, q_valid, pids_mat) -> np.ndarray:
        """Stage-3 centroid-interaction scores for delta candidates,
        -inf at -1 slots — bitwise the frozen ``approx`` for the same
        docs (same ``stage3_approx_score_batch``, same masking)."""
        from repro.core.plaid import stage3_approx_score_batch
        pids_mat = np.asarray(pids_mat)
        codes, _, valid = self._gather_delta(pids_mat, with_packed=False)
        approx = stage3_approx_score_batch(
            jnp.asarray(scores_c), jnp.asarray(codes), jnp.asarray(valid),
            jnp.asarray(q_valid))
        return np.where(pids_mat >= 0, np.asarray(approx), -np.inf).astype(
            np.float32)

    def exact_scores(self, q, q_valid, pids_mat) -> np.ndarray:
        """Exact decompress+MaxSim for delta candidates, -inf at -1
        slots — same kernel + argument shapes as
        ``PLAIDSearcher.score_gathered_lazy`` so per-candidate scores
        are bitwise what the frozen path computes."""
        pids_mat = np.asarray(pids_mat)
        codes, packed, valid = self._gather_delta(pids_mat, with_packed=True)
        scores = decompress_maxsim_scores_batch(
            jnp.asarray(q), jnp.asarray(packed),
            jnp.asarray(codes).astype(jnp.int32), jnp.asarray(valid),
            self._centroids_j, self._bweights_j, nbits=self.nbits,
            q_valid=jnp.asarray(q_valid))
        return np.where(pids_mat >= 0, np.asarray(scores), -np.inf).astype(
            np.float32)

    # -- compaction --------------------------------------------------------
    def snapshot_delta(self) -> int:
        """Number of delta docs safe to compact (append-only prefix)."""
        with self._lock:
            return len(self._doclens)

    def rebase(self, n_take: int):
        """Drop the compacted prefix and advance base_n. Global pids are
        unchanged (delta doc j becomes base doc base_n + j)."""
        with self._lock:
            del self._cids[:n_take]
            del self._packed[:n_take]
            del self._doclens[:n_take]
            del self._term_ids[:n_take]
            del self._term_weights[:n_take]
            self.base_n += n_take
            self.counters["compactions"] += 1
            self.counters["docs_compacted"] += n_take
            self._splade_cache = (None, None)
            self._ivf_cache = (None, None)


# --------------------------------------------------------------------------
# compaction: delta prefix → new on-disk index directories
# --------------------------------------------------------------------------

def compact_colbert_dir(index, live: LiveIndexState, n_take: int, out_dir):
    """Write a new ColBERT index dir = base + first ``n_take`` delta
    docs. Geometry (centroids/codec) is copied verbatim; codes/residuals
    are concatenated (delta rows were encoded with the same geometry,
    so the result is bitwise what the from-scratch builder produces for
    the concatenated corpus); the IVF is rebuilt over the full layout.
    """
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    base_codes = np.asarray(index.store.codes)
    base_res = np.asarray(index.store.residuals)
    d_cids = [live._cids[j] for j in range(n_take)]
    d_packed = [live._packed[j] for j in range(n_take)]
    d_lens = np.asarray([live._doclens[j] for j in range(n_take)], np.int32)

    codes = np.concatenate([base_codes] + d_cids) if n_take else base_codes
    res = np.vstack([base_res] + d_packed) if n_take else base_res
    PagedStore.write(out, codes, res, dim=index.dim, nbits=index.nbits)

    np.save(out / "centroids.npy", np.asarray(index.centroids))
    np.save(out / "bucket_cutoffs.npy", np.asarray(index.bucket_cutoffs))
    np.save(out / "bucket_weights.npy", np.asarray(index.bucket_weights))
    doclens = np.concatenate([np.asarray(index.doclens, np.int32), d_lens])
    n_docs = len(doclens)
    np.save(out / "doclens.npy", doclens)
    offsets = np.zeros(n_docs + 1, np.int64)
    np.cumsum(doclens, out=offsets[1:])
    np.save(out / "doc_offsets.npy", offsets)

    token_pids = np.repeat(np.arange(n_docs), doclens)
    iv = ivf_mod.build_ivf(codes, token_pids, index.n_centroids)
    iv.pids.tofile(out / "ivf_pids.bin")
    np.save(out / "ivf_offsets.npy", iv.offsets)

    meta = json.loads((out / "meta.json").read_text())
    meta.update({"n_docs": int(n_docs), "doc_maxlen": int(index.doc_maxlen),
                 "n_centroids": int(index.n_centroids)})
    (out / "meta.json").write_text(json.dumps(meta))

    # tombstones ride along for operators / cold restarts; serving keeps
    # them in RAM (pids are stable, so the set survives the swap as-is)
    np.save(out / "tombstones.npy", live.tombstone_array())
    return out


def compact_splade_dir(splade, live: LiveIndexState, n_take: int, out_dir):
    """Write a new SPLADE CSR dir = base postings + first ``n_take``
    delta docs' postings, re-sorted into the builder's (term, pid)
    order and quantised with the *base* quantum — bitwise the CSR a
    from-scratch build (pinned quantum) produces for the same corpus."""
    from repro.index.splade_index import SpladeIndex
    base_terms = np.repeat(np.arange(splade.vocab, dtype=np.int64),
                           np.diff(splade.term_offsets))
    base_pids = np.asarray(splade.pids, np.int64)
    base_imps = np.asarray(splade.impacts, np.uint8)

    ts, ps, ims = [base_terms], [base_pids], [base_imps]
    for j in range(n_take):
        t = live._term_ids[j]
        w = live._term_weights[j]
        keep = w > 0  # the same filter build_splade_index applies
        t, w = t[keep].astype(np.int64), w[keep]
        imp = np.clip(np.round(w / max(live.quantum, 1e-9)), 1, 255)
        ts.append(t)
        # local pid within *this* CSR: delta doc j lands after the base
        # docs of the segment being compacted (== live.base_n + j only
        # in the unsharded case; a shard group compacts into its last
        # shard, whose local base count is splade.n_docs)
        ps.append(np.full(t.shape, splade.n_docs + j, np.int64))
        ims.append(imp.astype(np.uint8))
    terms = np.concatenate(ts)
    pids = np.concatenate(ps)
    imps = np.concatenate(ims)
    order = np.lexsort((pids, terms))
    terms, pids, imps = terms[order], pids[order], imps[order]
    counts = np.bincount(terms, minlength=splade.vocab)
    offsets = np.zeros(splade.vocab + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    idx = SpladeIndex(term_offsets=offsets, pids=pids.astype(np.int32),
                      impacts=imps, quantum=float(splade.quantum),
                      n_docs=int(splade.n_docs + n_take),
                      vocab=int(splade.vocab))
    idx.save(out_dir)
    return pathlib.Path(out_dir)


# --------------------------------------------------------------------------
# rebuild oracle helpers (tests + churn soak)
# --------------------------------------------------------------------------

def map_global_to_ref(pids, survivors: np.ndarray):
    """Map global pids → reference (from-scratch rebuild) pids under
    the monotone bijection sorted(survivors) ↔ 0..n-1. -1 passes
    through. The map is monotone, so (score desc, pid asc) tie order —
    the total order every merge in this codebase uses — is preserved,
    and mapped top-k lists compare exactly."""
    pids = np.asarray(pids)
    out = np.full(pids.shape, -1, np.int64)
    m = pids >= 0
    out[m] = np.searchsorted(survivors, pids[m])
    return out


def build_reference_indexes(colbert_dir, splade_dir, doc_embs, doc_lens,
                            term_ids, term_weights, vocab, *,
                            centroids, bucket_cutoffs, bucket_weights,
                            nbits: int, quantum: float):
    """From-scratch rebuild of a (surviving) corpus with the serve
    index's frozen geometry pinned — the parity oracle."""
    from repro.index.builder import build_colbert_index
    from repro.index.splade_index import build_splade_index
    build_colbert_index(colbert_dir, np.asarray(doc_embs, np.float32),
                        np.asarray(doc_lens), nbits=nbits,
                        centroids=centroids, bucket_cutoffs=bucket_cutoffs,
                        bucket_weights=bucket_weights)
    spl = build_splade_index(np.asarray(term_ids), np.asarray(term_weights),
                             vocab, len(np.asarray(doc_lens)),
                             quantum=quantum)
    spl.save(splade_dir)
    return colbert_dir, splade_dir


class AutoCompactor(threading.Thread):
    """Background thread: compact when the delta segment crosses a
    threshold. Single-flight by construction (the only caller of
    ``compact_live`` on its retriever)."""

    def __init__(self, retriever, every: int, interval_s: float = 0.25):
        super().__init__(daemon=True, name="live-compactor")
        self.retriever = retriever
        self.every = int(every)
        self.interval_s = interval_s
        self._stop = threading.Event()

    def run(self):
        while not self._stop.wait(self.interval_s):
            live = getattr(self.retriever, "live", None)
            if live is not None and live.n_delta >= self.every:
                try:
                    self.retriever.compact_live()
                except Exception:  # pragma: no cover - surfaced via health
                    import traceback
                    traceback.print_exc()

    def stop(self):
        self._stop.set()
        self.join(timeout=5)
