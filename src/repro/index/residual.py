"""ColBERTv2 residual codec: centroid id + n-bit quantised residual.

Encoding (per token embedding e):
  cid  = argmax_c <e, centroid_c>
  r    = e − centroid_cid
  per-dim code = bucket index of r_d against global quantile cutoffs
  codes packed little-endian into uint8 (8/nbits codes per byte)

Decoding: e ≈ centroid_cid + bucket_weights[code].
This matches the ColBERTv2/PLAID codec structure (nbits ∈ {2, 4}).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ResidualCodec:
    centroids: jnp.ndarray       # (K, dim) float32, unit norm
    bucket_cutoffs: jnp.ndarray  # (2^nbits − 1,) float32
    bucket_weights: jnp.ndarray  # (2^nbits,) float32
    nbits: int

    @property
    def dim(self) -> int:
        return int(self.centroids.shape[1])

    @property
    def codes_per_byte(self) -> int:
        return 8 // self.nbits

    def packed_dim(self) -> int:
        return self.dim // self.codes_per_byte


def fit_codec(centroids, sample_embs, sample_cids, nbits: int) -> ResidualCodec:
    """Fit bucket cutoffs/weights from a residual sample (quantiles)."""
    res = np.asarray(sample_embs) - np.asarray(centroids)[np.asarray(sample_cids)]
    n_buckets = 2 ** nbits
    qs = np.linspace(0, 1, n_buckets + 1)[1:-1]
    cutoffs = np.quantile(res, qs)
    # bucket weight = mean residual value within the bucket
    bucket_ids = np.searchsorted(cutoffs, res.reshape(-1))
    sums = np.bincount(bucket_ids, weights=res.reshape(-1), minlength=n_buckets)
    cnts = np.maximum(np.bincount(bucket_ids, minlength=n_buckets), 1)
    weights = (sums / cnts).astype(np.float32)
    return ResidualCodec(
        centroids=jnp.asarray(centroids, jnp.float32),
        bucket_cutoffs=jnp.asarray(cutoffs, jnp.float32),
        bucket_weights=jnp.asarray(weights, jnp.float32),
        nbits=nbits,
    )


@functools.partial(jax.jit, static_argnames=("nbits",))
def encode_residuals(embs, cids, centroids, cutoffs, nbits: int):
    """embs: (N, dim) → packed codes (N, dim·nbits/8) uint8."""
    res = embs - centroids[cids]
    codes = jnp.searchsorted(cutoffs, res).astype(jnp.uint8)  # (N, dim)
    cpb = 8 // nbits
    N, dim = codes.shape
    grouped = codes.reshape(N, dim // cpb, cpb)
    shifts = (jnp.arange(cpb, dtype=jnp.uint8) * nbits)
    packed = jnp.sum(
        grouped.astype(jnp.uint32) << shifts.astype(jnp.uint32), axis=-1)
    return packed.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("nbits",))
def unpack_codes(packed, nbits: int):
    """packed: (..., dim/cpb) uint8 → codes (..., dim) uint8."""
    cpb = 8 // nbits
    mask = jnp.uint8(2 ** nbits - 1)
    shifts = (jnp.arange(cpb, dtype=jnp.uint8) * nbits)
    codes = (packed[..., None] >> shifts) & mask
    return codes.reshape(*packed.shape[:-1], packed.shape[-1] * cpb)


@functools.partial(jax.jit, static_argnames=("nbits",))
def decode_embeddings(packed, cids, centroids, bucket_weights, nbits: int):
    """→ (N, dim) float32 approximate embeddings."""
    codes = unpack_codes(packed, nbits)
    return centroids[cids] + bucket_weights[codes.astype(jnp.int32)]


def compression_ratio(dim: int, nbits: int) -> float:
    """fp32 embedding bytes vs (packed codes + 4-byte centroid id)."""
    raw = 4 * dim
    comp = dim * nbits / 8 + 4
    return raw / comp
