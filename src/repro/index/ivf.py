"""Inverted file: centroid id → postings of passage ids (CSR)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class IVF:
    pids: np.ndarray         # (total_postings,) int32, concatenated per centroid
    offsets: np.ndarray      # (K+1,) int64
    n_centroids: int

    def postings(self, cid: int) -> np.ndarray:
        return self.pids[self.offsets[cid]:self.offsets[cid + 1]]

    def max_list_len(self) -> int:
        return int(np.max(np.diff(self.offsets))) if len(self.pids) else 0

    def as_padded(self, pad_to: int | None = None):
        """Dense (K, pad) int32 with -1 fill — the device-resident form."""
        pad = pad_to or self.max_list_len()
        out = np.full((self.n_centroids, pad), -1, np.int32)
        for c in range(self.n_centroids):
            lst = self.postings(c)[:pad]
            out[c, :len(lst)] = lst
        return out


def build_ivf(token_cids: np.ndarray, token_pids: np.ndarray,
              n_centroids: int) -> IVF:
    """token_cids/token_pids: (n_tokens,) — centroid and passage of each
    token. A passage appears once per distinct centroid among its tokens."""
    pairs = np.stack([token_cids.astype(np.int64),
                      token_pids.astype(np.int64)], axis=1)
    pairs = np.unique(pairs, axis=0)
    cids, pids = pairs[:, 0], pairs[:, 1]
    order = np.argsort(cids, kind="stable")
    cids, pids = cids[order], pids[order]
    counts = np.bincount(cids, minlength=n_centroids)
    offsets = np.zeros(n_centroids + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return IVF(pids=pids.astype(np.int32), offsets=offsets,
               n_centroids=n_centroids)
