"""Impact-ordered inverted index for SPLADE — the PISA adaptation.

Postings are stored CSR by term with uint8-quantised impacts (the paper
uses PISA's ``block_simdbp`` with a quantised scorer; we keep the
quantisation and the term-at-a-time scoring, and replace SIMD posting
decompression with vectorised numpy / a JAX segment-sum path).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np


@dataclasses.dataclass
class SpladeIndex:
    term_offsets: np.ndarray   # (V+1,) int64
    pids: np.ndarray           # (nnz,) int32  (pid-ascending within term)
    impacts: np.ndarray        # (nnz,) uint8
    quantum: float             # impact = quantum * uint8
    n_docs: int
    vocab: int

    # ------------------------------------------------------------------
    def df(self, term: int) -> int:
        return int(self.term_offsets[term + 1] - self.term_offsets[term])

    def score_host(self, term_ids: np.ndarray, term_weights: np.ndarray,
                   k: int = 200):
        """Term-at-a-time exact scoring on the host (the PISA stand-in).

        term_ids: (Qt,) int32; term_weights: (Qt,) float32 (0 padding ok).
        Returns (pids (k,), scores (k,)) sorted desc; -1 padded."""
        scores = np.zeros(self.n_docs, np.float32)
        for t, w in zip(term_ids, term_weights):
            if w <= 0 or t < 0:
                continue
            s, e = self.term_offsets[t], self.term_offsets[t + 1]
            if e > s:
                np.add.at  # noqa: B018 — doc: scores[pids] += w*imp, vectorised
                scores[self.pids[s:e]] += w * self.quantum * \
                    self.impacts[s:e].astype(np.float32)
        k_eff = min(k, self.n_docs)
        top = np.argpartition(scores, -k_eff)[-k_eff:]
        top = top[np.argsort(-scores[top], kind="stable")]
        out_pids = np.full(k, -1, np.int64)
        out_scores = np.zeros(k, np.float32)
        out_pids[:k_eff] = top
        out_scores[:k_eff] = scores[top]
        # mark empty tail (score 0 and beyond corpus) as absent
        return out_pids, out_scores

    # ------------------------------------------------------------------
    def as_padded(self, max_df: int):
        """Fixed-shape postings for the JAX/TPU path: (V, max_df) pids
        (−1 fill) + impacts. Terms with df > max_df keep their top-impact
        postings (documented approximation; exactness measured in tests)."""
        V = self.vocab
        pids = np.full((V, max_df), -1, np.int32)
        imps = np.zeros((V, max_df), np.uint8)
        for t in range(V):
            s, e = self.term_offsets[t], self.term_offsets[t + 1]
            if e <= s:
                continue
            p, i = self.pids[s:e], self.impacts[s:e]
            if e - s > max_df:
                keep = np.argpartition(i, -(max_df))[-max_df:]
                p, i = p[keep], i[keep]
            pids[t, :len(p)] = p
            imps[t, :len(p)] = i
        return pids, imps

    # ------------------------------------------------------------------
    def save(self, path):
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        np.save(path / "term_offsets.npy", self.term_offsets)
        self.pids.tofile(path / "postings_pids.bin")
        self.impacts.tofile(path / "postings_imps.bin")
        (path / "meta.json").write_text(json.dumps({
            "quantum": self.quantum, "n_docs": self.n_docs,
            "vocab": self.vocab, "nnz": int(len(self.pids))}))

    @classmethod
    def load(cls, path, mmap: bool = False):
        path = pathlib.Path(path)
        meta = json.loads((path / "meta.json").read_text())
        if mmap:
            pids = np.memmap(path / "postings_pids.bin", np.int32, "r")
            imps = np.memmap(path / "postings_imps.bin", np.uint8, "r")
        else:
            pids = np.fromfile(path / "postings_pids.bin", np.int32)
            imps = np.fromfile(path / "postings_imps.bin", np.uint8)
        return cls(term_offsets=np.load(path / "term_offsets.npy"),
                   pids=pids, impacts=imps, quantum=meta["quantum"],
                   n_docs=meta["n_docs"], vocab=meta["vocab"])


def build_splade_index(doc_term_ids: np.ndarray, doc_term_weights: np.ndarray,
                       vocab: int, n_docs: int) -> SpladeIndex:
    """doc_term_ids/weights: (n_docs, T) top-T sparse representations
    (0-weight entries ignored)."""
    rows, cols = np.nonzero(doc_term_weights > 0)
    terms = doc_term_ids[rows, cols].astype(np.int64)
    weights = doc_term_weights[rows, cols].astype(np.float32)
    pids = rows.astype(np.int32)

    quantum = float(weights.max()) / 255.0 if len(weights) else 1.0
    imps = np.clip(np.round(weights / max(quantum, 1e-9)), 1, 255).astype(np.uint8)

    order = np.lexsort((pids, terms))
    terms, pids, imps = terms[order], pids[order], imps[order]
    counts = np.bincount(terms, minlength=vocab)
    offsets = np.zeros(vocab + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return SpladeIndex(term_offsets=offsets, pids=pids, impacts=imps,
                       quantum=quantum, n_docs=n_docs, vocab=vocab)


def splade_score_jax_padded(padded_pids, padded_imps, quantum, n_docs,
                            term_ids, term_weights, k: int):
    """JAX scorer over fixed-shape postings (the TPU path).

    padded_pids/imps: (V, max_df); term_ids: (Qt,); term_weights: (Qt,).
    Returns (top_pids (k,), top_scores (k,))."""
    import jax
    import jax.numpy as jnp

    p = padded_pids[term_ids]                     # (Qt, max_df)
    i = padded_imps[term_ids].astype(jnp.float32)  # (Qt, max_df)
    w = term_weights[:, None] * i * quantum
    valid = (p >= 0) & (term_weights[:, None] > 0)
    seg = jnp.where(valid, p, n_docs).reshape(-1)
    vals = jnp.where(valid, w, 0.0).reshape(-1)
    scores = jax.ops.segment_sum(vals, seg, num_segments=n_docs + 1)[:n_docs]
    top_scores, top_pids = jax.lax.top_k(scores, k)
    return top_pids.astype(jnp.int32), top_scores
