"""Impact-ordered inverted index for SPLADE — the PISA adaptation.

Postings are stored CSR by term with uint8-quantised impacts (the paper
uses PISA's ``block_simdbp`` with a quantised scorer; we keep the
quantisation and the term-at-a-time scoring, and replace SIMD posting
decompression with vectorised numpy / a JAX segment-sum path).
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np


def _topk_rows(scores: np.ndarray, k: int):
    """Row-wise descending top-k. scores: (B, n) → (pids (B, k) int64,
    scores (B, k) f32); rows are padded with (−1, 0) when k > n.

    Ties are broken by ascending pid — the same order ``jax.lax.top_k``
    uses on the device backends, and the property that makes a sharded
    index's per-shard top-k lists merge into exactly the single-index
    ranking (quantised uint8 impacts tie often, so an unstable
    partition here would make candidate sets irreproducible across
    shard counts). Selection stays O(n) per row: partition for the k-th
    value, keep everything above it, and fill the boundary from the
    pid-ascending scan of its ties — only the k survivors are sorted.
    """
    B, n = scores.shape
    k_eff = min(k, n)
    out_pids = np.full((B, k), -1, np.int64)
    out_scores = np.zeros((B, k), np.float32)
    if k_eff:
        if k_eff < n:
            kth = np.partition(scores, n - k_eff, axis=1)[:, n - k_eff,
                                                          None]
            above = scores > kth
            n_above = above.sum(axis=1, keepdims=True)
            ties = scores == kth
            keep = ties & (np.cumsum(ties, axis=1) <= k_eff - n_above)
            sel_mask = above | keep
        else:
            sel_mask = np.ones((B, n), bool)
        # nonzero scans row-major → exactly k_eff pids per row, ascending
        sel = np.nonzero(sel_mask)[1].reshape(B, k_eff)
        vals = np.take_along_axis(scores, sel, axis=1)
        order = np.argsort(-vals, axis=1, kind="stable")
        out_pids[:, :k_eff] = np.take_along_axis(sel, order, axis=1)
        out_scores[:, :k_eff] = np.take_along_axis(vals, order, axis=1)
    return out_pids, out_scores


@dataclasses.dataclass
class SpladeIndex:
    term_offsets: np.ndarray   # (V+1,) int64
    pids: np.ndarray           # (nnz,) int32  (pid-ascending within term)
    impacts: np.ndarray        # (nnz,) uint8
    quantum: float             # impact = quantum * uint8
    n_docs: int
    vocab: int

    # ------------------------------------------------------------------
    def df(self, term: int) -> int:
        return int(self.term_offsets[term + 1] - self.term_offsets[term])

    def score_host(self, term_ids: np.ndarray, term_weights: np.ndarray,
                   k: int = 200):
        """Term-at-a-time exact scoring on the host (the PISA stand-in).

        term_ids: (Qt,) int32; term_weights: (Qt,) float32 (0 padding ok).
        Returns (pids (k,), scores (k,)) sorted desc; -1 padded."""
        scores = np.zeros(self.n_docs, np.float32)
        for t, w in zip(term_ids, term_weights):
            if w <= 0 or t < 0:
                continue
            s, e = self.term_offsets[t], self.term_offsets[t + 1]
            if e > s:
                # np.add.at, not fancy-index +=: a pid repeated within a
                # term's postings must accumulate both impacts
                np.add.at(scores, self.pids[s:e],
                          np.float32(w * self.quantum)
                          * self.impacts[s:e].astype(np.float32))
        pids, top_scores = _topk_rows(scores[None], k)
        return pids[0], top_scores[0]

    def score_batch_host(self, term_ids, term_weights, k: int = 200,
                         exclude=None):
        """Vectorised multi-query host scoring (the no-device/mmap tier).

        term_ids/term_weights: sequences of (Qt_i,) arrays (ragged fine).
        One pass over the union of the batch's query terms: postings of
        each distinct term are gathered from the (possibly mmap'd) CSR
        arrays exactly once, then scattered into a (B, n_docs) score
        matrix with a single ``np.add.at`` — no per-query Python loop.
        Peak memory is ``4·B·n_docs`` bytes (vs one (n_docs,) vector per
        query sequentially) — size ``max_batch`` accordingly on very
        large host-tier corpora.

        ``exclude``: optional array of pids masked out *before* the
        top-k (live-index tombstones). Exclusion must happen pre-top-k
        so a tombstoned doc cannot displace a survivor from the k list
        — that is what keeps the filtered ranking identical to an index
        that never contained the doc. Legit scores are ≥ 0 (weights and
        impacts are non-negative), so excluded docs are marked with a
        negative sentinel and scrubbed to (-1, 0.0) pads afterwards.
        Returns (pids (B, k), scores (B, k)) sorted desc; -1 padded."""
        B = len(term_ids)
        scores = np.zeros((B, self.n_docs), np.float32)
        # flatten valid (query, term, weight) triples, query-major so the
        # scatter accumulates in the same order as per-query score_host
        qidx, terms, weights = [], [], []
        for i in range(B):
            t = np.asarray(term_ids[i]).astype(np.int64, copy=False)
            w = np.asarray(term_weights[i]).astype(np.float32, copy=False)
            keep = (w > 0) & (t >= 0)
            qidx.append(np.full(int(keep.sum()), i, np.int64))
            terms.append(t[keep])
            weights.append(w[keep])
        qidx = np.concatenate(qidx) if qidx else np.zeros(0, np.int64)
        terms = np.concatenate(terms) if terms else np.zeros(0, np.int64)
        weights = (np.concatenate(weights) if weights
                   else np.zeros(0, np.float32))
        if len(terms):
            # gather the union of posting lists once (one mmap touch per
            # distinct term even when co-batched queries share terms)
            uniq, inv = np.unique(terms, return_inverse=True)
            u_starts = self.term_offsets[uniq]
            u_lens = (self.term_offsets[uniq + 1] - u_starts).astype(np.int64)
            total = int(u_lens.sum())
            u_local = np.arange(total) - np.repeat(
                np.cumsum(u_lens) - u_lens, u_lens)
            u_flat = np.repeat(u_starts, u_lens) + u_local
            u_pids = np.asarray(self.pids[u_flat]).astype(np.int64,
                                                          copy=False)
            u_imps = self.impacts[u_flat].astype(np.float32)
            # expand per (query, term) entry into the gathered buffer
            u_offs = np.cumsum(u_lens) - u_lens        # term start in buffer
            e_lens = u_lens[inv]
            e_total = int(e_lens.sum())
            e_local = np.arange(e_total) - np.repeat(
                np.cumsum(e_lens) - e_lens, e_lens)
            e_src = np.repeat(u_offs[inv], e_lens) + e_local
            scale = (weights * np.float32(self.quantum)).astype(np.float32)
            vals = np.repeat(scale, e_lens) * u_imps[e_src]
            flat_target = np.repeat(qidx, e_lens) * self.n_docs \
                + u_pids[e_src]
            np.add.at(scores.reshape(-1), flat_target, vals)
        exclude = None if exclude is None else np.asarray(exclude, np.int64)
        if exclude is not None and exclude.size:
            scores[:, exclude] = -1.0
        pids, top = _topk_rows(scores, k)
        if exclude is not None and exclude.size:
            bad = top < 0
            pids[bad] = -1
            top[bad] = 0.0
        return pids, top

    # ------------------------------------------------------------------
    def as_padded(self, max_df: int):
        """Fixed-shape postings for the JAX/TPU path: (V, max_df) pids
        (−1 fill) + impacts. Terms with df > max_df keep their top-impact
        postings (documented approximation; exactness measured in tests)."""
        V = self.vocab
        pids = np.full((V, max_df), -1, np.int32)
        imps = np.zeros((V, max_df), np.uint8)
        for t in range(V):
            s, e = self.term_offsets[t], self.term_offsets[t + 1]
            if e <= s:
                continue
            p, i = self.pids[s:e], self.impacts[s:e]
            if e - s > max_df:
                keep = np.argpartition(i, -(max_df))[-max_df:]
                p, i = p[keep], i[keep]
            pids[t, :len(p)] = p
            imps[t, :len(p)] = i
        return pids, imps

    # ------------------------------------------------------------------
    def save(self, path):
        path = pathlib.Path(path)
        path.mkdir(parents=True, exist_ok=True)
        np.save(path / "term_offsets.npy", self.term_offsets)
        self.pids.tofile(path / "postings_pids.bin")
        self.impacts.tofile(path / "postings_imps.bin")
        (path / "meta.json").write_text(json.dumps({
            "quantum": self.quantum, "n_docs": self.n_docs,
            "vocab": self.vocab, "nnz": int(len(self.pids))}))

    @classmethod
    def load(cls, path, mmap: bool = False):
        path = pathlib.Path(path)
        meta = json.loads((path / "meta.json").read_text())
        if mmap:
            pids = np.memmap(path / "postings_pids.bin", np.int32, "r")
            imps = np.memmap(path / "postings_imps.bin", np.uint8, "r")
        else:
            pids = np.fromfile(path / "postings_pids.bin", np.int32)
            imps = np.fromfile(path / "postings_imps.bin", np.uint8)
        return cls(term_offsets=np.load(path / "term_offsets.npy"),
                   pids=pids, impacts=imps, quantum=meta["quantum"],
                   n_docs=meta["n_docs"], vocab=meta["vocab"])


def build_splade_index(doc_term_ids: np.ndarray, doc_term_weights: np.ndarray,
                       vocab: int, n_docs: int,
                       quantum: float | None = None) -> SpladeIndex:
    """doc_term_ids/weights: (n_docs, T) top-T sparse representations
    (0-weight entries ignored). ``quantum`` pins an externally-chosen
    quantisation step (live-index delta segments and rebuild-parity
    oracles must quantise with the *base* index's quantum so impacts
    stay bitwise comparable); default derives it from this corpus."""
    rows, cols = np.nonzero(doc_term_weights > 0)
    terms = doc_term_ids[rows, cols].astype(np.int64)
    weights = doc_term_weights[rows, cols].astype(np.float32)
    pids = rows.astype(np.int32)

    if quantum is None:
        quantum = float(weights.max()) / 255.0 if len(weights) else 1.0
    imps = np.clip(np.round(weights / max(quantum, 1e-9)), 1, 255).astype(np.uint8)

    order = np.lexsort((pids, terms))
    terms, pids, imps = terms[order], pids[order], imps[order]
    counts = np.bincount(terms, minlength=vocab)
    offsets = np.zeros(vocab + 1, np.int64)
    np.cumsum(counts, out=offsets[1:])
    return SpladeIndex(term_offsets=offsets, pids=pids, impacts=imps,
                       quantum=quantum, n_docs=n_docs, vocab=vocab)


def splade_score_jax_padded(padded_pids, padded_imps, quantum, n_docs,
                            term_ids, term_weights, k: int):
    """JAX scorer over fixed-shape postings, single query.

    padded_pids/imps: (V, max_df); term_ids: (Qt,); term_weights: (Qt,).
    Returns (top_pids (k,), top_scores (k,)). Thin wrapper over the
    shared segment-sum oracle — `SpladeDeviceCache` serves the batched
    production path on the same kernel family."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.splade_score.ref import splade_block_scores_ref

    p = padded_pids[term_ids]                      # (Qt, max_df)
    i = padded_imps[term_ids].astype(jnp.float32)  # (Qt, max_df)
    scores = splade_block_scores_ref(p, i, term_weights * quantum, n_docs)
    top_scores, top_pids = jax.lax.top_k(scores, k)
    return top_pids.astype(jnp.int32), top_scores
