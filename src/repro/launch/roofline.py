"""Roofline report generator: reads the dry-run JSON records and emits
the per-(arch × shape × mesh) table for EXPERIMENTS.md §Roofline.

Terms (per device, v5e):
  compute    = HLO_dot_FLOPs / 197 TFLOP/s (bf16)
  memory     = HLO_bytes (fusion-boundary traffic model) / 819 GB/s
  collective = ring-model wire bytes / 50 GB/s per ICI link

plus MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the dominant term.
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs.registry import all_cells

_HW = {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9}


def load_records(dry_dir: pathlib.Path, mesh: str, variant: str = "base"):
    suffix = f"__{mesh}.json" if variant == "base" else \
        f"__{mesh}__{variant}.json"
    recs = {}
    for p in dry_dir.glob(f"*{suffix}"):
        if variant == "base" and "__opt" in p.name:
            continue
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            recs[(r["arch"], r["shape"])] = r
    return recs


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def roofline_fraction(rec: dict) -> float:
    """Useful-FLOPs bound: model FLOPs / (dominant-term time × peak)."""
    r = rec["roofline"]
    bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
    if bound_s <= 0:
        return 0.0
    return rec["model_flops_per_dev"] / (bound_s * _HW["peak_flops"])


def table(dry_dir: pathlib.Path, mesh: str, *, fmt: str = "md") -> str:
    recs = load_records(dry_dir, mesh)
    rows = []
    header = ("| arch | shape | compute | memory | collective | dominant "
              "| model/HLO flops | roofline frac | mem/dev |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for arch, shape, sd in all_cells(include_skipped=False):
        r = recs.get((arch, shape))
        if r is None:
            rows.append(f"| {arch} | {shape} | - | - | - | MISSING | | | |")
            continue
        rl = r["roofline"]
        mem_gb = (r["memory"]["argument_size_in_bytes"]
                  + r["memory"]["temp_size_in_bytes"]) / 2 ** 30
        rows.append(
            f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| **{rl['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {roofline_fraction(r):.3f} "
            f"| {mem_gb:.1f} GiB |")
    return "\n".join(rows)


def pick_hillclimb_targets(dry_dir: pathlib.Path, mesh: str = "single"):
    """worst roofline fraction / most collective-bound / most
    paper-representative."""
    recs = load_records(dry_dir, mesh)
    scored = []
    for (arch, shape), r in recs.items():
        rl = r["roofline"]
        total = rl["compute_s"] + rl["memory_s"] + rl["collective_s"]
        coll_frac = rl["collective_s"] / total if total else 0
        scored.append({"arch": arch, "shape": shape,
                       "frac": roofline_fraction(r),
                       "coll_frac": coll_frac, "dominant": rl["dominant"]})
    worst = min(scored, key=lambda s: s["frac"] if s["frac"] > 0 else 1e9)
    most_coll = max(scored, key=lambda s: s["coll_frac"])
    paper = next(s for s in scored
                 if s["arch"] == "colbert-serve" and s["shape"] == "serve_plaid")
    return worst, most_coll, paper


def compare_table(dry_dir: pathlib.Path, mesh: str) -> str:
    """Baseline vs hillclimbed variants, for cells that have both."""
    base = load_records(dry_dir, mesh, "base")
    opt = load_records(dry_dir, mesh, "opt")
    rows = ["| arch | shape | base bound | opt bound | gain | opt dominant |",
            "|" + "---|" * 6]

    def bound(r):
        rl = r["roofline"]
        return max(rl["compute_s"], rl["memory_s"], rl["collective_s"])

    for key in sorted(opt):
        if key not in base:
            continue
        b, o = bound(base[key]), bound(opt[key])
        rows.append(
            f"| {key[0]} | {key[1]} | {fmt_s(b)} | {fmt_s(o)} "
            f"| **{b / max(o, 1e-12):.1f}×** "
            f"| {opt[key]['roofline']['dominant']} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--targets", action="store_true")
    ap.add_argument("--compare", action="store_true",
                    help="baseline vs optimized variants")
    args = ap.parse_args()
    d = pathlib.Path(args.dry_dir)
    if args.compare:
        print(compare_table(d, args.mesh))
        return
    print(table(d, args.mesh))
    if args.targets:
        w, c, p = pick_hillclimb_targets(d, args.mesh)
        print("\nhillclimb targets:")
        print("  worst roofline :", w)
        print("  most collective:", c)
        print("  paper technique:", p)


if __name__ == "__main__":
    main()
