"""Serving launcher: bring up the concurrent ColBERT-serve stack.

    PYTHONPATH=src python -m repro.launch.serve \
        [--method hybrid] [--threads 1] [--port 8080] [--qps 2.0]

Builds (or loads with --index-dir) a ColBERT + SPLADE index, starts the
worker pool and the TCP front, and either serves forever (--port) or
runs a bounded Poisson load and prints the latency report.
"""

from __future__ import annotations

import argparse
import pathlib
import tempfile
import threading

import numpy as np

from repro.core.multistage import MultiStageParams, MultiStageRetriever
from repro.core.plaid import PLAIDSearcher, PlaidParams
from repro.core.sharded import build_shard_group
from repro.core.store import PAGE_BYTES
from repro.data.synth import SynthCfg, make_corpus
from repro.index.builder import ColBERTIndex, build_colbert_index
from repro.index.sharding import split_index_tree
from repro.index.splade_index import SpladeIndex, build_splade_index
from repro.launch.mesh import shard_device_map
from repro.serving.admission import AdmissionController
from repro.serving.context import CacheHierarchy
from repro.serving.engine import Request, ServeEngine
from repro.serving.loadgen import (
    load_trace,
    run_open_loop,
    run_poisson_load,
    zipf_trace,
)
from repro.serving.server import RetrievalServer


def build_or_load(index_dir: str | None, mode: str,
                  splade_backend: str = "host",
                  splade_max_df: int | None = None,
                  rerank_backend: str = "fused",
                  n_shards: int = 1, shard_workers: str = "thread",
                  shard_transport: str | None = None,
                  arena_bytes: int | None = None,
                  replicas: int = 1,
                  replica_endpoints: str | None = None,
                  allow_degraded: bool = False,
                  op_deadline_ms: float | None = None,
                  hedge_factor: float = 0.0,
                  hedge_floor_ms: float = 50.0):
    """Build (or load) the serving index and retriever. ``n_shards >= 2``
    splits the single index into a contiguous-range shard group on disk
    (``<dir>/shards/``, reused if already split at this count) and
    returns a scatter-gather retriever over it: ``shard_workers=
    "thread"`` keeps the group in this process (stage-1 device caches
    mapped round-robin onto the local devices); ``"process"`` spawns
    one shared-nothing worker process per shard (own mmap segment, own
    page cache, own GIL) behind an RPC coordinator — results are
    bitwise-identical across both backends. ``shard_transport`` picks
    the process-worker tensor path (``shm`` zero-copy ring arenas /
    ``socket`` stream; None = platform default) and ``arena_bytes``
    sizes each worker's per-direction ring.

    The replica knobs (process workers only) configure the fleet
    fabric: ``replicas`` local workers per shard plus any
    ``replica_endpoints`` (``"h:p,h:p;h:p"`` — ``;`` between shards,
    ``,`` between that shard's remote workers), health-aware failover
    between them, ``op_deadline_ms`` per-op deadlines, hedged requests
    past ``hedge_factor``× the replica's EWMA latency, and
    ``allow_degraded`` partial answers when every replica of a shard
    is down."""
    if index_dir and (pathlib.Path(index_dir) / "colbert").exists():
        base = pathlib.Path(index_dir)
        corpus = None
    else:
        cfg = SynthCfg(n_docs=3000, n_queries=300, seed=0)
        corpus = make_corpus(cfg)
        base = pathlib.Path(index_dir or tempfile.mkdtemp(prefix="serve_"))
        build_colbert_index(base / "colbert", corpus["doc_embs"],
                            corpus["doc_lens"], nbits=4,
                            n_centroids=256, kmeans_iters=4)
        build_splade_index(corpus["doc_term_ids"],
                           corpus["doc_term_weights"], cfg.vocab,
                           cfg.n_docs).save(base / "splade")
    plaid_params = PlaidParams(nprobe=4, candidate_cap=1024, ndocs=256)
    ms_params = MultiStageParams(first_k=200, alpha=0.3,
                                 splade_backend=splade_backend,
                                 splade_max_df=splade_max_df,
                                 rerank_backend=rerank_backend)
    if n_shards > 1 or shard_workers == "process":
        from repro.index.sharding import load_group
        group = split_index_tree(base, n_shards)
        shard_dirs, boundaries = load_group(group)
        fleet_kw = {}
        if shard_workers == "process":
            fleet_kw = dict(replicas=replicas,
                            replica_endpoints=replica_endpoints,
                            allow_degraded=allow_degraded,
                            op_deadline_ms=op_deadline_ms,
                            hedge_factor=hedge_factor,
                            hedge_floor_ms=hedge_floor_ms)
        retr = build_shard_group(
            shard_dirs, boundaries, workers=shard_workers, mode=mode,
            plaid_params=plaid_params, multistage_params=ms_params,
            transport=shard_transport, arena_bytes=arena_bytes,
            devices=(None if shard_workers == "process"
                     else shard_device_map(n_shards)), **fleet_kw)
        # the unsharded index handle is informational only (pool-size
        # print) — serving reads the per-shard segments, so always open
        # it mmap: a second full-RAM copy of the pool would double
        # resident memory under --mode ram
        return corpus, ColBERTIndex(base / "colbert", mode="mmap"), retr
    index = ColBERTIndex(base / "colbert", mode=mode)
    sidx = SpladeIndex.load(base / "splade", mmap=(mode == "mmap"))
    retr = MultiStageRetriever(sidx, PLAIDSearcher(index, plaid_params),
                               ms_params)
    return corpus, index, retr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index-dir", default=None)
    ap.add_argument("--mode", default="mmap", choices=["mmap", "ram"])
    ap.add_argument("--method", default="hybrid")
    ap.add_argument("--threads", type=int, default=1)
    ap.add_argument("--splade-backend", default="host",
                    choices=["host", "jax", "pallas"],
                    help="stage-1 scorer: host CSR pass, device "
                         "segment-sum, or the Pallas block kernel")
    ap.add_argument("--splade-max-df", type=int, default=None,
                    help="padded-postings df cap for jax/pallas "
                         "(memory vs exactness; default: exact)")
    ap.add_argument("--rerank-backend", default="fused",
                    choices=["fused", "split"],
                    help="stage-4 tail: fused = decompress + MaxSim + "
                         "top-k in ONE device dispatch (the tiled "
                         "fused_rerank kernel on TPU, a fused XLA tail "
                         "elsewhere), split = the legacy multi-dispatch "
                         "tail. Results are bitwise-identical; fused "
                         "degrades to split when Pallas is unavailable")
    ap.add_argument("--shards", type=int, default=1,
                    help=">=2: partition the index into this many "
                         "contiguous doc-range shards (scatter-gather "
                         "serving with a global top-k merge; per-shard "
                         "mmap segments fault pages in parallel)")
    ap.add_argument("--shard-workers", default="thread",
                    choices=["thread", "process"],
                    help="shard group backend: in-process thread "
                         "fanouts, or one shared-nothing worker "
                         "process per shard (own mmap page cache + "
                         "GIL) behind the scatter-gather RPC — "
                         "bitwise-identical results")
    ap.add_argument("--shard-transport", default=None,
                    choices=["shm", "socket"],
                    help="process-worker tensor transport: shm = "
                         "zero-copy shared-memory ring arenas (one per "
                         "worker, /dev/shm), socket = in-frame sendmsg "
                         "segments over the socketpair; default picks "
                         "shm when /dev/shm is writable")
    ap.add_argument("--arena-bytes", type=int, default=None,
                    help="per-direction ring capacity of each worker's "
                         "shm arena (bounds in-flight tensor bytes; "
                         "default auto-sizes, see launch.mesh."
                         "shard_arena_bytes)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="local worker processes per shard (process "
                         "workers only; >=2 enables health-aware "
                         "failover between interchangeable replicas)")
    ap.add_argument("--replica-endpoints", default=None,
                    help="remote standalone workers per shard, "
                         "'host:port,host:port;host:port' — ';' "
                         "separates shards, ',' that shard's remote "
                         "replicas (each runs `python -m repro.serving"
                         ".worker --shard-dir … --port …`)")
    ap.add_argument("--allow-degraded", action="store_true",
                    help="when every replica of a shard is down, "
                         "serve partial results merged over the "
                         "surviving shards (responses carry degraded="
                         "true + the missing shard ids) instead of "
                         "failing the request")
    ap.add_argument("--op-deadline-ms", type=float, default=None,
                    help="per-op RPC deadline; an expired op fails "
                         "over to a sibling replica (or raises "
                         "DeadlineExceeded with one replica)")
    ap.add_argument("--hedge-factor", type=float, default=0.0,
                    help=">0 hedges stragglers: an op still pending "
                         "past factor×EWMA of its replica's latency "
                         "is re-sent on a sibling (shard ops are "
                         "pure, so duplicates are safe)")
    ap.add_argument("--hedge-floor-ms", type=float, default=50.0,
                    help="minimum hedge budget, so cold EWMAs don't "
                         "hedge every op")
    ap.add_argument("--max-batch", type=int, default=1)
    ap.add_argument("--batch-timeout-ms", type=float, default=2.0)
    ap.add_argument("--latency-slo-ms", type=float, default=None,
                    help="enable adaptive micro-batch sizing against "
                         "this service-time SLO")
    ap.add_argument("--pipeline", action="store_true",
                    help="stage-graph pipelining at the default depth "
                         "(2, double-buffered)")
    ap.add_argument("--pipeline-depth", type=int, default=None,
                    help="batches in flight: 1 = synchronous, "
                         ">=2 overlaps micro-batch N+1's mmap gather "
                         "with batch N's device dispatch")
    ap.add_argument("--pipeline-workers", default="single",
                    choices=["single", "kind"],
                    help="executor scheduling: single-worker software "
                         "pipelining (async dispatch; best under the "
                         "GIL) or per-kind host/device worker threads "
                         "(multi-core hosts / TPU)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="strictly open-loop Poisson arrivals at this "
                         "QPS (instead of the default generator)")
    ap.add_argument("--cache-exact", type=int, default=0,
                    help="exact result cache entries (0 = off): a hit "
                         "returns the bitwise cold answer straight "
                         "from the front door")
    ap.add_argument("--cache-stage1", type=int, default=0,
                    help="stage-1/candidate cache entries (0 = off): "
                         "cached SPLADE unions / PLAID candidate sets "
                         "skip the stage-1 dispatch on repeat queries")
    ap.add_argument("--admission-slo-ms", type=float, default=None,
                    help="SLO-aware admission: when per-stage EWMAs "
                         "predict a request blows this budget, degrade "
                         "it to the splade-only plan or shed it")
    ap.add_argument("--shed-factor", type=float, default=3.0,
                    help="shed when even the degraded plan is "
                         "predicted past factor×SLO")
    ap.add_argument("--skew", type=float, default=0.0,
                    help="Zipf skew of the bounded load's query "
                         "sampling (0 = round-robin; >0 draws queries "
                         "with popularity ∝ 1/rank^skew — the repeat-"
                         "heavy traffic caches are for)")
    ap.add_argument("--replay", default=None,
                    help="replay a query-index trace file (one index "
                         "per line) instead of sampling")
    ap.add_argument("--live", action="store_true",
                    help="enable the mutable index: upsert/delete/"
                         "compact ops on the TCP front (new docs land "
                         "in an in-RAM delta segment, deletes are "
                         "tombstones filtered at the merges; needs "
                         "--mode mmap)")
    ap.add_argument("--live-compact-every", type=int, default=None,
                    help="background compaction threshold: merge the "
                         "delta segment into a new index generation "
                         "whenever it reaches this many docs (implies "
                         "--live)")
    ap.add_argument("--port", type=int, default=None,
                    help="serve forever on this TCP port (0 binds an "
                         "ephemeral port and prints the real one); "
                         "omit to run the bounded load test instead")
    ap.add_argument("--qps", type=float, default=2.0)
    ap.add_argument("--n", type=int, default=60)
    args = ap.parse_args()

    depth = (args.pipeline_depth if args.pipeline_depth is not None
             else (2 if args.pipeline else 1))
    corpus, index, retr = build_or_load(
        args.index_dir, args.mode, args.splade_backend,
        args.splade_max_df, rerank_backend=args.rerank_backend,
        n_shards=args.shards,
        shard_workers=args.shard_workers,
        shard_transport=args.shard_transport,
        arena_bytes=args.arena_bytes,
        replicas=args.replicas,
        replica_endpoints=args.replica_endpoints,
        allow_degraded=args.allow_degraded,
        op_deadline_ms=args.op_deadline_ms,
        hedge_factor=args.hedge_factor,
        hedge_floor_ms=args.hedge_floor_ms)
    # backend already configured (and device cache pre-materialised) via
    # MultiStageParams in build_or_load; the engine owns the retriever so
    # a process shard group's workers are reaped on every exit path
    compactor = None
    if args.live or args.live_compact_every is not None:
        retr.enable_live()
        if args.live_compact_every is not None:
            from repro.index.live import AutoCompactor
            compactor = AutoCompactor(retr, args.live_compact_every)
            compactor.start()
    caches = None
    if args.cache_exact > 0 or args.cache_stage1 > 0:
        caches = CacheHierarchy(exact_entries=args.cache_exact,
                                stage1_entries=args.cache_stage1)
    admission = None
    if args.admission_slo_ms is not None:
        admission = AdmissionController(args.admission_slo_ms,
                                        shed_factor=args.shed_factor)
    engine = ServeEngine(retr, pipeline_depth=depth,
                         pipeline_workers=args.pipeline_workers,
                         own_retriever=True, caches=caches)
    server = RetrievalServer(
        engine, n_threads=args.threads, max_batch=args.max_batch,
        batch_timeout_ms=args.batch_timeout_ms,
        latency_slo_ms=args.latency_slo_ms, admission=admission)
    server.start()
    rb = getattr(retr, "rerank_backend", args.rerank_backend)
    if rb != args.rerank_backend:
        print(f"rerank backend {args.rerank_backend!r} unavailable "
              f"(no Pallas toolchain) — falling back to {rb!r}")
    print(f"serving ({args.mode} index, {args.threads} thread(s), "
          f"stage1={args.splade_backend}, rerank={rb}, "
          f"pipeline_depth={depth}, "
          f"shards={args.shards} [{args.shard_workers} workers]); "
          f"pool={index.store.total_bytes() / 1e6:.1f} MB")

    try:
        if args.port is not None:
            tcp = server.serve_tcp("0.0.0.0", args.port)
            server.install_sigterm_handler()   # graceful drain on TERM
            print(f"TCP front on :{server.tcp_port} (newline-delimited "
                  f"JSON; SIGTERM or Ctrl-C to stop)")
            try:
                tcp.serve_forever()
            except KeyboardInterrupt:
                pass
            finally:
                server.shutdown_gracefully()
            return

        assert corpus is not None, \
            "the bounded load test needs a built-in corpus"
        n_unique = len(corpus["q_embs"])
        if args.replay is not None:
            trace = load_trace(args.replay) % n_unique
            trace = trace[:args.n] if len(trace) >= args.n else \
                np.resize(trace, args.n)
        elif args.skew > 0:
            trace = zipf_trace(args.n, n_unique, skew=args.skew, seed=0)
        else:
            trace = np.arange(args.n) % n_unique
        reqs = [Request(qid=i, method=args.method,
                        q_emb=corpus["q_embs"][q],
                        term_ids=corpus["q_term_ids"][q],
                        term_weights=corpus["q_term_weights"][q],
                        k=20, trace_id=int(q))
                for i, q in enumerate(trace)]
        if args.arrival_rate is not None:
            res = run_open_loop(server, reqs,
                                arrival_rate=args.arrival_rate, seed=0)
        else:
            res = run_poisson_load(server, reqs, qps=args.qps, seed=0,
                                   burst=args.max_batch)
        s = res.summary()
        print(f"offered {s['offered_qps']:.2f} QPS → achieved "
              f"{s['achieved_qps']:.2f}; p50 {s['p50'] * 1e3:.1f} ms, "
              f"p95 {s['p95'] * 1e3:.1f} ms, p99 {s['p99'] * 1e3:.1f} ms")
        print(f"trace: {s['unique_queries']} unique / "
              f"{s['repeat_queries']} repeats; outcomes: "
              f"{s['cache_hits']} cache hits, {s['degraded']} degraded, "
              f"{s['shed']} shed, {s['failed']} failed")
        if caches is not None:
            cs = caches.stats()
            print(f"caches: exact {cs['exact']['hits']}h/"
                  f"{cs['exact']['misses']}m "
                  f"(size {cs['exact']['size']}/"
                  f"{cs['exact']['capacity']}), stage1 "
                  f"{cs['stage1']['hits']}h/{cs['stage1']['misses']}m "
                  f"(size {cs['stage1']['size']}/"
                  f"{cs['stage1']['capacity']})")
        if admission is not None:
            ast = admission.stats()
            print(f"admission: {ast['full_admits']} full, "
                  f"{ast['degraded_admits']} degraded, "
                  f"{ast['sheds']} shed "
                  f"(SLO {ast['latency_slo_ms']:.0f} ms)")
        if depth > 1:
            h = server.health()
            print(f"pipeline overlap: "
                  f"{100 * h.get('overlap_fraction', 0.0):.1f}% "
                  f"(stage queues: {h['pipeline']['queues']})")
        if hasattr(retr, "worker_health"):
            # process group: the aggregate pool is split across worker
            # working sets, not replicated into the coordinator
            for w in retr.worker_health():
                print(f"shard worker {w['shard']}: pid={w['pid']} "
                      f"rss={w.get('rss_bytes', 0) / 1e6:.1f} MB "
                      f"segment={w.get('pool_bytes', 0) / 1e6:.1f} MB "
                      f"served={w.get('served', 0)} "
                      f"transport={w.get('transport', '?')} "
                      f"copied={w.get('rpc_bytes_copied', 0) / 1e6:.2f}"
                      f" MB zero_copy="
                      f"{w.get('rpc_bytes_zero_copy', 0) / 1e6:.2f} MB")
        else:
            # in-process serving: the gathers hit this process's stores
            # (per-shard segments under thread sharding)
            stores = ([sh.searcher.index.store for sh in retr.shards]
                      if hasattr(retr, "shards") else [index.store])
            touched = sum(len(s.stats.unique_pages or ())
                          for s in stores)
            total = sum(max(1, s.total_bytes() // PAGE_BYTES)
                        for s in stores)
            print(f"mmap working set: {100 * touched / total:.1f}% of "
                  f"pool" + (f" ({len(stores)} segments)"
                             if len(stores) > 1 else ""))
        server.drain()
        server.stop()
    finally:
        if compactor is not None:
            compactor.stop()
        engine.close()     # stops pipelines + reaps shard workers


if __name__ == "__main__":
    main()
