"""Static analysis of post-SPMD optimized HLO text.

``compiled.cost_analysis()`` on this backend counts a ``while`` body
exactly once, so scan-over-layers models would be undercounted by the
layer count. This parser rebuilds the totals with loop multipliers:

* per-computation **dot FLOPs** (2 · |out| · |contraction|) — the models
  here are dot-dominated, elementwise FLOPs are ignored (documented);
* per-computation **HBM traffic estimate**: Σ over top-level
  instructions of (output bytes + operand bytes) for memory-moving ops
  (fusions, dots, copies, slices, collectives) — i.e. every top-level
  op reads its operands from and writes its result to HBM, which is the
  fusion-boundary approximation XLA itself uses for roofline estimates;
* per-computation **collective wire bytes** with ring-model factors:
  all-gather / all-to-all: out·(g−1)/g; all-reduce: 2·out·(g−1)/g;
  reduce-scatter: out·(g−1); collective-permute: out;
* a call-graph walk (while trip counts from the loop condition's
  comparison constant, conditional = max over branches) to scale nested
  computations.

All quantities are **per device** (the input is the partitioned
module). Validated against analytic 6·N·D FLOPs in tests.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# top-level op kinds that we bill as HBM traffic
_MEM_OPS = ("fusion", "dot", "convolution", "copy", "dynamic-slice",
            "dynamic-update-slice", "gather", "scatter", "slice",
            "concatenate", "broadcast", "transpose", "reshape", "reduce",
            "sort", "iota", "pad", "select-and-scatter", "convert",
            "cholesky", "triangular-solve") + COLLECTIVES


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes mentioned in a type string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str):
    """(dtype, dims tuple) of the first array shape in the text."""
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    kind: str
    out_bytes: int
    out_shape: Optional[tuple]
    body: str
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict[str, Instr]
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    # (callee, multiplier, via) edges
    calls: list = dataclasses.field(default_factory=list)
    trip_hint: int = 1
    is_entry: bool = False


def _op_kind(body: str) -> str:
    """The HLO opcode: first token after the result type."""
    # body looks like: "bf16[8,128]{1,0} fusion(%a, %b), kind=kLoop, ..."
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", body)
    return m.group(1) if m else ""


def _dot_flops(instr: Instr, table: dict[str, Instr]) -> float:
    """2 · |out| · |contraction| from lhs shape + contracting dims."""
    if instr.out_shape is None or not instr.operands:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.body)
    lhs = table.get(instr.operands[0])
    if m is None or lhs is None or lhs.out_shape is None:
        return 0.0
    _, out_dims = instr.out_shape
    _, lhs_dims = lhs.out_shape
    contr = 1
    for d in m.group(1).split(","):
        if d != "" and int(d) < len(lhs_dims):
            contr *= lhs_dims[int(d)]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    return 2.0 * out_elems * contr


def _group_size(body: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(body)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(body)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip()]), 1)
    return n_devices


def _collective_wire_bytes(kind: str, out_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather" or kind == "all-to-all":
        return out_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * out_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(out_bytes) * (g - 1)
    if kind == "collective-permute":
        return float(out_bytes)
    return 0.0


def parse_module(text: str, n_devices: int = 1) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        ls = line.strip()
        if not ls:
            continue
        if (ls.startswith("HloModule") or ls.startswith("//")
                or ls.startswith("#")):
            continue
        # computation header: "%name (params) -> type {" or "ENTRY %name..."
        if ls.endswith("{") and ("(" in ls) and "=" not in ls.split("(")[0]:
            is_entry = ls.startswith("ENTRY")
            header = ls.split("(")[0].replace("ENTRY", "").strip()
            name = header.lstrip("%").strip()
            cur = Computation(name=name, instrs={}, is_entry=is_entry)
            comps[name] = cur
            continue
        if ls.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(ls)
        if not m:
            continue
        name, body = m.group(1), m.group(2)
        kind = _op_kind(body)
        # result type = text before the opcode
        type_text = body.split(f" {kind}(")[0] if kind else body
        out_bytes = _shape_bytes(type_text)
        out_shape = _first_shape(type_text)
        paren = body[body.find("("):] if "(" in body else ""
        arg_text = paren.split("),")[0] if ")," in paren else paren
        operands = _OPND_RE.findall(arg_text)
        cur.instrs[name] = Instr(name=name, kind=kind, out_bytes=out_bytes,
                                 out_shape=out_shape, body=body,
                                 operands=operands)
    # per-computation statistics
    for comp in comps.values():
        table = comp.instrs
        for ins in table.values():
            if ins.kind == "dot" or ins.kind == "convolution":
                comp.flops += _dot_flops(ins, table)
            if ins.kind == "fusion":
                # dots inside fusions are printed as calls=%fused_comp —
                # billed when walking that computation via the edge below
                callee = re.search(r"calls=%?([\w.\-]+)", ins.body)
                if callee:
                    comp.calls.append((callee.group(1), 1.0, "fusion"))
            if ins.kind in COLLECTIVES:
                g = _group_size(ins.body, n_devices)
                # async pairs: -start billed, -done skipped via bytes=0 out
                wire = _collective_wire_bytes(
                    ins.kind, ins.out_bytes, g)
                comp.coll_bytes += wire
                comp.coll_by_kind[ins.kind] = \
                    comp.coll_by_kind.get(ins.kind, 0.0) + wire
            # memory billing happens in _compute_mem (fusion-aware)
            if ins.kind == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.body)
                bodyc = re.search(r"body=%?([\w.\-]+)", ins.body)
                trip = 1
                if cond and cond.group(1) in comps:
                    consts = [int(x) for x in _TRIP_RE.findall(
                        "\n".join(i.body for i in
                                  comps[cond.group(1)].instrs.values()))]
                    trip = max(consts) if consts else 1
                elif cond:
                    trip = 0  # resolved in second pass
                if bodyc:
                    comp.calls.append((bodyc.group(1), max(trip, 1),
                                       "while"))
            if ins.kind in ("call", "custom-call"):
                callee = re.search(r"to_apply=%?([\w.\-]+)", ins.body)
                if callee:
                    comp.calls.append((callee.group(1), 1.0, "call"))
            if ins.kind == "conditional":
                for mm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"\w+_computation=%?([\w.\-]+))",
                                      ins.body):
                    names = mm.group(1) or mm.group(2) or ""
                    for nm in names.replace("%", "").split(","):
                        nm = nm.strip()
                        if nm:
                            comp.calls.append((nm, 1.0, "cond"))
    return comps


_INDEXED_READS = ("gather", "dynamic-slice")
_INDEXED_WRITES = ("scatter", "dynamic-update-slice")


def _param_index(ins: Instr) -> Optional[int]:
    m = re.search(r"parameter\((\d+)\)", ins.body)
    return int(m.group(1)) if m else None


def _fusion_operand_bytes(callee: Computation, op_idx: int,
                          full_bytes: int) -> float:
    """Bytes a fusion actually reads from operand ``op_idx``.

    If the corresponding parameter inside the fused computation is only
    consumed by indexed reads (gather/dynamic-slice), the fusion touches
    just the addressed rows — bill Σ of those reads' outputs. Otherwise
    the whole operand streams through."""
    pname = None
    for ins in callee.instrs.values():
        if ins.kind == "parameter" and _param_index(ins) == op_idx:
            pname = ins.name
            break
    if pname is None:
        return float(full_bytes)
    consumers = [i for i in callee.instrs.values()
                 if pname in i.operands]
    if not consumers:
        return 0.0
    if all(c.kind in _INDEXED_READS and c.operands
           and c.operands[0] == pname for c in consumers):
        return float(sum(c.out_bytes for c in consumers))
    return float(full_bytes)


def _compute_mem(comps: dict[str, Computation]):
    """Fusion-boundary HBM-traffic model.

    Only *top-level* computations (not fusion bodies) move HBM bytes:
    every top-level instruction writes its output and reads its
    operands, with indexed reads/writes billed by the moved region and
    fusion operands refined through ``_fusion_operand_bytes``."""
    fused = {c for comp in comps.values()
             for c, _, via in comp.calls if via == "fusion"}
    for comp in comps.values():
        table = comp.instrs
        mem = 0.0
        for ins in table.values():
            if ins.kind not in _MEM_OPS:
                continue
            if ins.kind in _INDEXED_READS:
                idx = sum(table[o].out_bytes
                          for o in ins.operands[1:] if o in table)
                mem += 2 * ins.out_bytes + idx
            elif ins.kind in _INDEXED_WRITES:
                upd = (table[ins.operands[1]].out_bytes
                       if len(ins.operands) > 1
                       and ins.operands[1] in table else ins.out_bytes)
                idx = sum(table[o].out_bytes
                          for o in ins.operands[2:] if o in table)
                mem += 2 * upd + idx
            elif ins.kind == "fusion":
                callee_m = re.search(r"calls=%?([\w.\-]+)", ins.body)
                callee = comps.get(callee_m.group(1)) if callee_m else None
                mem += ins.out_bytes
                for oi, o in enumerate(ins.operands):
                    full = table[o].out_bytes if o in table else 0
                    mem += (_fusion_operand_bytes(callee, oi, full)
                            if callee is not None else full)
            else:
                mem += ins.out_bytes + sum(
                    table[o].out_bytes for o in ins.operands if o in table)
        comp.mem_bytes = mem
    # fusion bodies execute in registers/VMEM: no HBM traffic of their own
    for name in fused:
        if name in comps:
            comps[name].mem_bytes = 0.0


def _resolve_trips(comps: dict[str, Computation]):
    """Second pass: while instrs whose cond constants live in comps
    parsed later get their trip counts re-resolved."""
    for comp in comps.values():
        new_calls = []
        for ins in comp.instrs.values():
            if ins.kind != "while":
                continue
            cond = re.search(r"condition=%?([\w.\-]+)", ins.body)
            bodyc = re.search(r"body=%?([\w.\-]+)", ins.body)
            if not (cond and bodyc):
                continue
            trip = 1
            if cond.group(1) in comps:
                consts = [int(x) for x in _TRIP_RE.findall(
                    "\n".join(i.body for i in
                              comps[cond.group(1)].instrs.values()))]
                trip = max(consts) if consts else 1
            new_calls.append((bodyc.group(1), max(trip, 1), "while"))
        kept = [c for c in comp.calls if c[2] != "while"]
        comp.calls = kept + new_calls


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    mem_bytes: float
    coll_bytes: float
    coll_by_kind: dict


def analyze(text: str, n_devices: int = 1,
            entry: Optional[str] = None) -> ModuleCosts:
    comps = parse_module(text, n_devices)
    _compute_mem(comps)
    _resolve_trips(comps)
    if not comps:
        return ModuleCosts(0, 0, 0, {})
    if entry is None:
        marked = [n for n, c in comps.items() if c.is_entry]
        if marked:
            entry = marked[0]
        else:
            called = {c for comp in comps.values() for c, _, _ in comp.calls}
            entries = [n for n in comps if n not in called]
            entry = (entries[-1] if entries else next(iter(comps)))

    memo: dict[str, ModuleCosts] = {}

    def walk(name: str, depth=0) -> ModuleCosts:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return ModuleCosts(0, 0, 0, {})
        memo[name] = ModuleCosts(0, 0, 0, {})  # cycle guard
        f, mb, cb = comp.flops, comp.mem_bytes, comp.coll_bytes
        by_kind = dict(comp.coll_by_kind)
        for callee, mult, _via in comp.calls:
            sub = walk(callee, depth + 1)
            f += mult * sub.flops
            mb += mult * sub.mem_bytes
            cb += mult * sub.coll_bytes
            for k, v in sub.coll_by_kind.items():
                by_kind[k] = by_kind.get(k, 0.0) + mult * v
        out = ModuleCosts(f, mb, cb, by_kind)
        memo[name] = out
        return out

    return walk(entry)
