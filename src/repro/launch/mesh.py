"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_local_mesh():
    """1-device mesh with the production axis names — lets the same
    pjit'd code paths run in tests/benchmarks on one CPU device."""
    return make_mesh((1, 1), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
