"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import os
from typing import Optional

import jax

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_local_mesh():
    """1-device mesh with the production axis names — lets the same
    pjit'd code paths run in tests/benchmarks on one CPU device."""
    return make_mesh((1, 1), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def shard_device_map(n_shards: int, devices=None) -> list:
    """Map a serving shard group onto devices, round-robin.

    Shard i's device-resident state (SPLADE padded postings, and on the
    device-resident PLAID path the token pool) is pinned to the returned
    ``devices[i % n]``, so a shard group's stage-1 ``jax``/``pallas``
    dispatches execute on distinct accelerators instead of queueing on
    the default device. ``devices`` defaults to ``jax.devices()``; on a
    single-device host every shard maps to that device (parallelism
    then comes from the host-side gather fanout only)."""
    if devices is None:
        devices = jax.devices()
    if not devices:
        raise ValueError("no devices to map shards onto")
    return [devices[i % len(devices)] for i in range(n_shards)]


def default_shard_transport() -> str:
    """Pick the tensor transport for process shard workers.

    ``shm`` (zero-copy ring arenas) whenever a writable ``/dev/shm``
    exists — the normal case on Linux serving hosts; ``socket``
    (in-frame ``sendmsg`` segments) otherwise. Overridable per launch
    via ``--shard-transport`` and per group via
    ``build_shard_group(transport=…)``."""
    shm = "/dev/shm"
    if os.path.isdir(shm) and os.access(shm, os.W_OK):
        return "shm"
    return "socket"


def shard_arena_bytes(n_workers: int,
                      requested: Optional[int] = None) -> int:
    """Per-direction ring capacity for each worker's shm arena.

    The arena bounds in-flight tensor bytes per worker (allocation
    back-pressure), so it must cover a few pipelined micro-batches of
    query tensors + candidate slices + reply scores — tens of MB, not
    the index size (index bytes never cross the transport; workers mmap
    their own shard subtree). 64 MiB/direction is comfortable for
    depth≲4 pipelines; when many workers share a small ``/dev/shm``,
    the cap splits a 1 GiB budget evenly rather than oversubscribing
    tmpfs."""
    if requested is not None:
        return max(1 << 20, int(requested))
    budget = 1 << 30
    per = min(64 << 20, budget // max(1, 2 * n_workers))
    return max(8 << 20, per)


def shard_worker_env(n_workers: int, *, pin_host_threads: bool = False,
                     base: Optional[dict] = None) -> dict:
    """Environment for spawned shard *worker processes*.

    Inherits the parent env and pins ``JAX_PLATFORMS`` to ``cpu``
    unless the caller already set it: most accelerators are
    single-owner per host, and N worker processes racing to initialise
    the same device would fail (the coordinator keeps the accelerator;
    workers own the mmap/host side).

    ``pin_host_threads`` restricts each worker's XLA CPU compute to one
    thread — worth it when ``n_workers`` approaches the core count so
    the workers' kernels don't thrash each other's cores. **Off by
    default**: a different intra-op thread count changes floating-point
    reduction order, and the process-group parity contract (process ==
    thread == shards-1, bitwise) requires workers to run the exact XLA
    configuration the coordinator would have used."""
    env = dict(os.environ if base is None else base)
    env.setdefault("JAX_PLATFORMS", "cpu")
    if pin_host_threads and n_workers > 1 and "XLA_FLAGS" not in env:
        env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                            "intra_op_parallelism_threads=1")
    return env
