"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_local_mesh():
    """1-device mesh with the production axis names — lets the same
    pjit'd code paths run in tests/benchmarks on one CPU device."""
    return make_mesh((1, 1), ("data", "model"))


def mesh_device_count(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


def shard_device_map(n_shards: int, devices=None) -> list:
    """Map a serving shard group onto devices, round-robin.

    Shard i's device-resident state (SPLADE padded postings, and on the
    device-resident PLAID path the token pool) is pinned to the returned
    ``devices[i % n]``, so a shard group's stage-1 ``jax``/``pallas``
    dispatches execute on distinct accelerators instead of queueing on
    the default device. ``devices`` defaults to ``jax.devices()``; on a
    single-device host every shard maps to that device (parallelism
    then comes from the host-side gather fanout only)."""
    if devices is None:
        devices = jax.devices()
    if not devices:
        raise ValueError("no devices to map shards onto")
    return [devices[i % len(devices)] for i in range(n_shards)]
