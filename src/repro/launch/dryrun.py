import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
# init, and the production meshes below need 512 placeholder devices.

import argparse          # noqa: E402
import json              # noqa: E402
import pathlib           # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.cells import build_cell          # noqa: E402
from repro.configs.registry import ARCHS, all_cells, get_arch  # noqa: E402
from repro.launch import hlo_analysis               # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_device_count  # noqa: E402

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
  jit(step).lower(input_specs) → compile → memory_analysis +
  cost_analysis + post-SPMD HLO collective/FLOP analysis → JSON record.

The 16×16 single-pod mesh (256 chips) and the 2×16×16 multi-pod mesh
(512 chips) must both compile for every live cell — failures here are
sharding bugs in the system. Results feed EXPERIMENTS.md §Dry-run and
§Roofline.
"""

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link


def _mesh(mesh_name: str):
    return make_production_mesh(multi_pod=(mesh_name == "multi"))


def _mem_dict(mem) -> dict:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes")
    return {k: int(getattr(mem, k, -1)) for k in keys}


def model_flops_estimate(arch_name: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6·N(active)·D for LM training, 2·N·D for a
    forward pass; family-specific estimates otherwise (global, all
    chips)."""
    from repro.common.utils import count_params
    arch = get_arch(arch_name)
    sd = arch.shapes[shape_name]
    if arch.family == "lm":
        from repro.models import transformer as T
        cfg = arch.full_cfg()
        params = T.abstract_init(cfg)
        n_total = count_params(params)
        # active params: replace MoE expert count by top_k + shared
        n_active = n_total
        for blocks, n in cfg.segments:
            for b in blocks:
                if b.ffn_kind == "moe":
                    m = b.moe
                    per_exp = 3 * m.d_model * m.d_ff_expert
                    n_active -= n * per_exp * (m.n_experts - m.top_k)
        d = sd.dims
        tokens = d["global_batch"] * (d["seq"] if sd.kind != "decode" else 1)
        mult = 6.0 if sd.kind == "train" else 2.0
        return mult * n_active * tokens
    if arch.family == "gnn":
        # per-edge message cost dominates: E · (K² mixing + K·81 couple)
        cfg = arch.full_cfg()
        K = cfg.d_hidden
        E = sd.dims["n_edges"]
        per_edge = 2 * K * K + 3 * 81 * K * 2
        per_node = 4 * 81 * 81 * K * 2        # product basis couplings
        N = sd.dims["n_nodes"]
        fwd = cfg.n_layers * (E * per_edge + N * per_node)
        return (3.0 if sd.kind == "train" else 1.0) * fwd
    if arch.family == "recsys":
        from repro.configs.cells import _recsys_module
        mod = _recsys_module(arch.name)
        cfg = arch.full_cfg()
        params = jax.eval_shape(
            lambda: mod.init(jax.random.PRNGKey(0), cfg))
        dense = count_params(params)
        # embedding tables are lookups, not matmuls: exclude them
        for k in ("tables", "item_embed", "lr_weight", "out_bias"):
            pass
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        table = sum(x.size for p, x in flat
                    if any(s in "/".join(str(q) for q in p)
                           for s in ("tables", "item_embed", "lr_weight",
                                     "out_bias")))
        dense -= table
        d = sd.dims
        work_items = d.get("batch", 1) * max(
            getattr(cfg, "seq_len", 1), 1) + d.get("n_candidates", 0)
        mult = 6.0 if sd.kind == "train" else 2.0
        return mult * dense * work_items
    if arch.family == "retrieval":
        cfg = arch.full_cfg()
        from repro.common.utils import count_params as cp
        from repro.models import colbert as CB
        enc_params = 110e6
        d = sd.dims
        if shape_name == "train_contrastive":
            toks = d["batch"] * (cfg.colbert.query_maxlen
                                 + cfg.colbert.doc_maxlen)
            inter = (d["batch"] ** 2 * cfg.colbert.query_maxlen
                     * cfg.colbert.doc_maxlen * cfg.colbert.dim * 2)
            return 6 * enc_params * toks / 2 + inter
        if shape_name == "encode_corpus":
            return 2 * enc_params * d["batch"] * cfg.colbert.doc_maxlen
        if shape_name == "serve_rerank":
            C = d["first_k"]
        else:
            C = d["ndocs"] + 0.1 * d["candidate_cap"]
        return (d["batch"] * C * cfg.index.doc_maxlen
                * cfg.colbert.query_maxlen * cfg.index.dim * 2)
    return 0.0


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             out_dir: pathlib.Path, *, force: bool = False,
             save_hlo: bool = False, tag: str = "",
             variant: str = "base") -> dict:
    if variant != "base":
        tag = f"{tag}__{variant}"
    key = f"{arch_name}__{shape_name}__{mesh_name}{tag}"
    out_path = out_dir / f"{key}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        if rec.get("status") == "ok":
            print(f"[cached] {key}: compile {rec['t_compile_s']:.1f}s")
            return rec

    mesh = _mesh(mesh_name)
    n_dev = mesh_device_count(mesh)
    arch = get_arch(arch_name)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "n_devices": n_dev, "status": "ok"}
    try:
        with mesh:
            if variant == "base":
                cell = build_cell(arch, shape_name, mesh)
            else:
                from repro.configs.cells_opt import build_cell_opt
                cell = build_cell_opt(arch, shape_name, mesh)
                if cell is None:
                    raise ValueError(
                        f"no optimized variant for {arch_name}×{shape_name}")
            t0 = time.time()
            lowered = jax.jit(
                cell.fn, donate_argnums=cell.donate_argnums
            ).lower(*cell.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

        mem = compiled.memory_analysis()
        print(f"[{key}] memory_analysis:", mem)
        try:
            ca = compiled.cost_analysis() or {}
        except Exception:
            ca = {}
        print(f"[{key}] cost_analysis flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}")
        text = compiled.as_text()
        costs = hlo_analysis.analyze(text, n_devices=n_dev)
        if save_hlo:
            (out_dir / f"{key}.hlo.txt").write_text(text)

        mflops = model_flops_estimate(arch_name, shape_name)
        per_dev_model = mflops / n_dev
        compute_s = costs.flops / PEAK_FLOPS
        memory_s = costs.mem_bytes / HBM_BW
        coll_s = costs.coll_bytes / ICI_BW
        dom = max((compute_s, "compute"), (memory_s, "memory"),
                  (coll_s, "collective"))[1]
        rec.update({
            "t_lower_s": t1 - t0, "t_compile_s": t2 - t1,
            "memory": _mem_dict(mem),
            "xla_cost_analysis": {k: float(v) for k, v in ca.items()
                                  if isinstance(v, (int, float))},
            "hlo_flops_per_dev": costs.flops,
            "hlo_bytes_per_dev": costs.mem_bytes,
            "collective_bytes_per_dev": costs.coll_bytes,
            "collective_by_kind": costs.coll_by_kind,
            "model_flops_global": mflops,
            "model_flops_per_dev": per_dev_model,
            "useful_flops_ratio": (per_dev_model / costs.flops
                                   if costs.flops else 0.0),
            "roofline": {
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dom,
            },
        })
        print(f"[{key}] compile={t2 - t1:.1f}s  "
              f"compute={compute_s * 1e3:.2f}ms  "
              f"memory={memory_s * 1e3:.2f}ms  "
              f"collective={coll_s * 1e3:.2f}ms  dominant={dom}")
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{key}] FAILED: {rec['error']}")
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all for the arch)")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--variant", default="base", choices=["base", "opt"])
    args = ap.parse_args()

    cells = all_cells()
    if args.arch:
        cells = [(a, s, d) for a, s, d in cells if a == args.arch]
    if args.shape:
        cells = [(a, s, d) for a, s, d in cells if s == args.shape]
    if args.list:
        for a, s, d in cells:
            print(f"{a:30s} {s:20s} {d.kind}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_dir = pathlib.Path(args.out)
    n_fail = 0
    for mesh_name in meshes:
        for a, s, _ in cells:
            rec = run_cell(a, s, mesh_name, out_dir, force=args.force,
                           save_hlo=args.save_hlo, variant=args.variant)
            n_fail += rec["status"] != "ok"
    print(f"\ndone: {len(cells) * len(meshes)} cells, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
