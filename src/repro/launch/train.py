"""Training launcher: fault-tolerant contrastive ColBERT training.

    PYTHONPATH=src python -m repro.launch.train \
        [--steps 200] [--batch 16] [--ckpt-dir ckpts] [--compress q8]

Wires the encoder, the synthetic pair stream, AdamW (+optional 8-bit
state), gradient compression with error feedback, and the
checkpoint/restart loop. Re-running the same command resumes from the
latest committed checkpoint; SIGTERM triggers a final save.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.colbert_serve import smoke_cfg
from repro.data.synth import make_token_corpus
from repro.models import colbert as CB
from repro.training.compression import CompressionCfg
from repro.training.optimizer import AdamWCfg
from repro.training.train_loop import LoopCfg, SeekableData, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default="ckpts/colbert")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quantize-opt-state", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=["none", "q8", "topk"])
    ap.add_argument("--n-docs", type=int, default=256)
    args = ap.parse_args()

    ccfg = smoke_cfg().colbert
    rng = np.random.default_rng(0)
    doc_toks, doc_lens = make_token_corpus(rng, args.n_docs,
                                           ccfg.encoder.vocab,
                                           ccfg.doc_maxlen)

    def make_batch(step):
        r = np.random.default_rng(step)
        idx = r.integers(0, args.n_docs, args.batch)
        q = doc_toks[idx, :ccfg.query_maxlen].copy()
        noise = r.random(q.shape) < 0.15
        q[noise] = r.integers(4, ccfg.encoder.vocab, noise.sum())
        return {"q_tokens": jnp.asarray(q),
                "q_lens": jnp.full((args.batch,), ccfg.query_maxlen,
                                   jnp.int32),
                "d_tokens": jnp.asarray(doc_toks[idx]),
                "d_lens": jnp.asarray(doc_lens[idx])}

    def loss_fn(params, batch):
        q = CB.encode_queries(params, ccfg, batch["q_tokens"],
                              batch["q_lens"])
        d, dv = CB.encode_docs(params, ccfg, batch["d_tokens"],
                               batch["d_lens"])
        s = jnp.einsum("qik,bjk->qbij", q, d)
        s = jnp.where(dv[None, :, None, :], s, -1e30)
        scores = jnp.sum(jnp.maximum(jnp.max(s, -1), 0.0), -1)
        logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
        nll = -jnp.mean(jnp.diag(logp))
        return nll, {"nll": nll}

    params = CB.init(jax.random.PRNGKey(0), ccfg)
    opt = AdamWCfg(lr=args.lr, weight_decay=0.01, warmup_steps=20,
                   total_steps=args.steps,
                   quantize_state=args.quantize_opt_state)
    loop = LoopCfg(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   ckpt_dir=args.ckpt_dir,
                   compression=CompressionCfg(kind=args.compress))
    params, _, report = run(loss_fn, params, SeekableData(make_batch),
                            opt, loop, install_sigterm=True)
    if report.resumed_from:
        print(f"resumed from step {report.resumed_from}")
    if report.preempted:
        print(f"preempted at step {report.final_step} (state saved)")
    if report.losses:
        print(f"loss {report.losses[0]:.4f} → {report.losses[-1]:.4f} "
              f"({report.final_step} steps; "
              f"{len(report.straggler_steps)} straggler steps)")


if __name__ == "__main__":
    main()
